//! Persistent execution engine (the repeated-solve substrate).
//!
//! The paper's headline number is the *repeated-solve* path: the same
//! pattern is refactored and resolved thousands of times inside a
//! simulation loop. Spawning OS threads and allocating O(n) scratch on
//! every `factor`/`refactor`/`forward`/`backward` call — what
//! `std::thread::scope` drivers do — is pure per-call overhead there
//! (CKTSO and ShyLU-node report the same effect). This module amortizes it
//! once:
//!
//! - [`WorkerPool`] — long-lived parked workers with epoch/job dispatch.
//!   Each worker owns a persistent [`Workspace`] arena that grows to the
//!   high-water mark during warm-up and is reused verbatim afterwards.
//! - [`ExecPlan`] — per-[`crate::symbolic::Symbolic`] schedule state
//!   (flop-balanced bulk-level chunks, substitution chunks, kernel
//!   scratch high-water bounds) computed once in `Solver::analyze`
//!   instead of on every numeric call.
//! - [`Engine`] — the pool plus the coordinator-side scratch: a
//!   [`ScratchPool`] of [`SolveScratch`] arenas (per-call checkout, so
//!   concurrent `solve*` callers overlap instead of serializing on one
//!   mutex) and a [`FactorScratch`] (pipeline done-flags + the cached
//!   permuted-matrix value buffers used by `refactor`), which stays
//!   behind a mutex because (re)factorization is exclusive by nature.
//!
//! Worker threads spawn **lazily** on the first dispatch, so analyze-only
//! uses (`hylu inspect`, the fig4 bench) never spawn at all. After one
//! warm-up `factor` + `solve`, a `refactor` + `solve` cycle dispatches
//! jobs onto already-running threads and performs **zero** O(n) scratch
//! allocations; [`PoolCounters`] makes both properties observable (and
//! assertable in tests).

pub mod plan;
pub mod scratch;

pub use plan::ExecPlan;
pub use scratch::{ScratchGuard, ScratchPool, MAX_SCRATCH_SLOTS};

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::thread::JoinHandle;

/// Lock ignoring poison: the pool propagates job panics *by design* (the
/// panicking frame holds the caller-context / scratch guards), and every
/// guarded structure is left in a consistent state on that path (workspaces
/// are scrubbed, scratch arenas are plain buffers), so a poisoned mutex
/// must not brick the engine.
pub(crate) fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Unwrap a condvar-wait result the same way.
pub(crate) fn wait_ignore_poison<T>(r: LockResult<MutexGuard<'_, T>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// Unwrap a condvar-wait-timeout result the same way. The timed-out
/// flag is dropped: callers re-check their predicate against the clock,
/// which subsumes it.
pub(crate) fn wait_timeout_ignore_poison<'a, T>(
    r: LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)>,
) -> MutexGuard<'a, T> {
    match r {
        Ok((g, _)) => g,
        Err(p) => p.into_inner().0,
    }
}

use crate::numeric::Workspace;
use crate::sparse::csr::Csr;

/// Observable engine behavior: thread spawns and scratch-arena growth.
/// These counters back the "zero threads, zero O(n) allocations after
/// warm-up" guarantee with assertions instead of folklore.
#[derive(Debug, Default)]
pub struct PoolCounters {
    /// OS threads spawned by the engine since construction.
    pub threads_spawned: AtomicUsize,
    /// Scratch-arena growth events (worker workspaces + solve scratch).
    pub scratch_allocs: AtomicU64,
    /// Jobs dispatched onto the pool.
    pub dispatches: AtomicU64,
    /// Times a worker's adaptive spin budget was halved after it had to
    /// park on the condvar (dispatch inter-arrival grew past the spin
    /// window). Lets tests observe the decay directly.
    pub spin_decays: AtomicU64,
    /// Workspace scrubs performed because a job panicked (each caught
    /// panic scrubs the affected worker contexts before the engine is
    /// reused). Fault-tolerance telemetry for the service layer.
    pub panic_scrubs: AtomicU64,
}

impl PoolCounters {
    /// Record one scratch-arena growth event.
    pub fn note_alloc(&self) {
        self.scratch_allocs.fetch_add(1, Ordering::Relaxed);
    }
}

/// Grow `v` to at least `n` elements (zero-filled), accounting the growth.
pub fn ensure_len(v: &mut Vec<f64>, n: usize, counters: &PoolCounters) {
    if v.len() < n {
        v.resize(n, 0.0);
        counters.note_alloc();
    }
}

/// Per-worker state handed to every job: persistent numeric workspaces
/// (one arena per factor precision — the [`ExecPlan`] high-water bounds
/// are element counts, so each arena sizes itself independently and only
/// the precisions actually used ever allocate) plus the shared counters
/// for allocation accounting.
pub struct WorkerCtx {
    /// Worker index in `[0, nthreads)`; worker 0 is the dispatching thread.
    pub id: usize,
    ws: Workspace,
    ws32: Workspace<f32>,
    counters: Arc<PoolCounters>,
}

impl WorkerCtx {
    fn new(id: usize, counters: Arc<PoolCounters>) -> Self {
        WorkerCtx {
            id,
            ws: Workspace::empty(),
            ws32: Workspace::empty(),
            counters,
        }
    }

    /// The worker's `f64` workspace, grown for dimension `n` and with
    /// kernel scratch reserved to the given high-water capacities. Growth
    /// is counted as a scratch allocation; after warm-up this is a no-op.
    pub fn workspace(
        &mut self,
        n: usize,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> &mut Workspace {
        let mut grew = self.ws.ensure(n);
        grew |= self.ws.reserve_kernel(cbuf, tbuf, map_idx, pbuf, abuf);
        if grew {
            self.counters.note_alloc();
        }
        &mut self.ws
    }

    /// The worker's `f32` workspace (mixed-precision factorization), with
    /// the same grow-once accounting as [`WorkerCtx::workspace`]. A
    /// worker that never factors in `f32` never allocates this arena.
    pub fn workspace_f32(
        &mut self,
        n: usize,
        cbuf: usize,
        tbuf: usize,
        map_idx: usize,
        pbuf: usize,
        abuf: usize,
    ) -> &mut Workspace<f32> {
        let mut grew = self.ws32.ensure(n);
        grew |= self.ws32.reserve_kernel(cbuf, tbuf, map_idx, pbuf, abuf);
        if grew {
            self.counters.note_alloc();
        }
        &mut self.ws32
    }

    /// Scrub every precision's arena after a job panic (scatter state in
    /// `x`/`colmap` may be mid-flight; see [`crate::numeric::Workspace`]).
    fn scrub_all(&mut self) {
        self.ws.scrub();
        self.ws32.scrub();
        self.counters.panic_scrubs.fetch_add(1, Ordering::Relaxed);
    }
}

/// Type-erased job pointer shipped to workers. Lifetime is erased; safety
/// comes from [`WorkerPool::run`] blocking until every worker has finished
/// the job before the referent can go out of scope.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize, &mut WorkerCtx) + Sync + 'static));

// Safety: the pointee is only dereferenced between dispatch and the
// all-done handshake, while the dispatching stack frame is pinned inside
// `WorkerPool::run`; the `Sync` bound makes shared calls sound.
unsafe impl Send for JobPtr {}

struct JobState {
    epoch: u64,
    job: Option<JobPtr>,
    remaining: usize,
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    cv_work: Condvar,
    cv_done: Condvar,
    /// Advisory epoch mirror for the workers' pre-park spin phase.
    epoch_hint: AtomicU64,
    /// Spin iterations before parking on the condvar.
    spin: u32,
}

/// A persistent pool of parked worker threads with epoch-based job
/// dispatch.
///
/// A pool of width `t` owns `t - 1` OS threads, spawned **lazily on the
/// first dispatch** — a pool that never dispatches (analyze-only paths)
/// never spawns; the dispatching thread itself acts as worker 0, so a
/// pool of size 1 never spawns at all and runs jobs inline.
/// [`WorkerPool::run`] publishes one job (a `Fn(worker, &mut WorkerCtx)`
/// executed by every worker exactly once) and blocks until all workers
/// finish — which is what makes handing out borrows of the caller's
/// stack to the workers sound. Dispatches are serialized by an internal
/// lock, so a `&WorkerPool` can be shared freely.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Worker 0 (caller) context; doubles as the dispatch lock.
    caller_ctx: Mutex<WorkerCtx>,
    /// Spawned worker handles (empty until the first dispatch).
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Whether the `nthreads - 1` workers have been spawned yet.
    spawned: AtomicBool,
    nthreads: usize,
    counters: Arc<PoolCounters>,
}

/// Default pre-park spin (iterations) — keeps sub-millisecond repeated
/// solves from paying a futex wakeup per dispatch without burning cores
/// when idle.
pub const DEFAULT_SPIN: u32 = 2048;

impl WorkerPool {
    /// Pool with `nthreads` total workers (including the caller) and the
    /// default spin; counters are created internally.
    pub fn new(nthreads: usize) -> Self {
        WorkerPool::with_counters(nthreads, DEFAULT_SPIN, Arc::new(PoolCounters::default()))
    }

    /// Pool wired to externally owned counters (the [`Engine`] shares one
    /// counter block between the pool and the coordinator scratch).
    /// Worker threads are not spawned here — see [`WorkerPool`].
    pub fn with_counters(nthreads: usize, spin: u32, counters: Arc<PoolCounters>) -> Self {
        let nthreads = nthreads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            cv_work: Condvar::new(),
            cv_done: Condvar::new(),
            epoch_hint: AtomicU64::new(0),
            spin,
        });
        WorkerPool {
            shared,
            caller_ctx: Mutex::new(WorkerCtx::new(0, counters.clone())),
            handles: Mutex::new(Vec::new()),
            spawned: AtomicBool::new(false),
            nthreads,
            counters,
        }
    }

    /// Spawn the `nthreads - 1` workers if they are not running yet.
    /// Called with the dispatch lock held, so at most one dispatcher
    /// races the check; the `handles` lock additionally orders it
    /// against `Drop`.
    fn ensure_spawned(&self) {
        if self.nthreads <= 1 || self.spawned.load(Ordering::Acquire) {
            return;
        }
        let mut handles = lock_ignore_poison(&self.handles);
        if !handles.is_empty() {
            return;
        }
        for id in 1..self.nthreads {
            let sh = self.shared.clone();
            let ct = self.counters.clone();
            self.counters.threads_spawned.fetch_add(1, Ordering::Relaxed);
            let h = std::thread::Builder::new()
                .name(format!("hylu-worker-{id}"))
                .spawn(move || worker_loop(sh, id, ct))
                .expect("spawn pool worker");
            handles.push(h);
        }
        self.spawned.store(true, Ordering::Release);
    }

    /// Total workers (caller included).
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Shared counters.
    pub fn counters(&self) -> &Arc<PoolCounters> {
        &self.counters
    }

    /// Dispatch `job` to every worker (each sees its worker id and its
    /// persistent [`WorkerCtx`]) and block until all of them return.
    ///
    /// `setup` runs under the dispatch lock *before* any worker can see
    /// the job — per-call shared state (e.g. resetting an [`ExecPlan`]'s
    /// done-flags) goes there so back-to-back dispatches from different
    /// threads cannot interleave setup with a running job.
    ///
    /// Panics in any worker (or the caller's share) are caught, the
    /// dispatch is drained so borrows stay sound, and the panic is then
    /// propagated on the calling thread. Caveat: that guarantee holds
    /// only for jobs without internal cross-worker synchronization — if a
    /// job's surviving workers block on a `Barrier` (or spin on a done
    /// flag) the panicked worker will never reach, the dispatch cannot
    /// drain and the call hangs, exactly as the scoped-thread drivers did.
    /// The factor/substitution drivers rely on up-front input validation
    /// to keep their jobs panic-free. Do not dispatch from inside a job —
    /// the inner dispatch would deadlock on the dispatch lock.
    #[allow(clippy::useless_transmute)] // lifetime-only transmute below
    pub fn run<S, F>(&self, setup: S, job: F)
    where
        S: FnOnce(),
        F: Fn(usize, &mut WorkerCtx) + Sync,
    {
        let mut ctx0 = lock_ignore_poison(&self.caller_ctx);
        self.counters.dispatches.fetch_add(1, Ordering::Relaxed);
        setup();
        if self.nthreads == 1 {
            let r = catch_unwind(AssertUnwindSafe(|| job(0, &mut ctx0)));
            if let Err(p) = r {
                ctx0.scrub_all();
                resume_unwind(p);
            }
            return;
        }
        self.ensure_spawned();
        let job_ref: &(dyn Fn(usize, &mut WorkerCtx) + Sync) = &job;
        // Safety: lifetime erasure only — see `JobPtr`.
        let ptr = JobPtr(unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, &mut WorkerCtx) + Sync),
                *const (dyn Fn(usize, &mut WorkerCtx) + Sync + 'static),
            >(job_ref)
        });
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.job = Some(ptr);
            st.remaining = self.nthreads - 1;
            st.panicked = false;
            st.epoch += 1;
            self.shared.epoch_hint.store(st.epoch, Ordering::Release);
            self.shared.cv_work.notify_all();
        }
        let caller_result = catch_unwind(AssertUnwindSafe(|| job(0, &mut ctx0)));
        let worker_panicked = {
            let mut st = lock_ignore_poison(&self.shared.state);
            while st.remaining > 0 {
                st = wait_ignore_poison(self.shared.cv_done.wait(st));
            }
            st.job = None;
            st.panicked
        };
        if let Err(p) = caller_result {
            ctx0.scrub_all();
            resume_unwind(p);
        }
        if worker_panicked {
            panic!("a pool worker panicked during the dispatched job");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
            self.shared.epoch_hint.store(u64::MAX, Ordering::Release);
            self.shared.cv_work.notify_all();
        }
        for h in lock_ignore_poison(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize, counters: Arc<PoolCounters>) {
    let mut ctx = WorkerCtx::new(id, counters);
    let mut seen = 0u64;
    // Adaptive pre-park spin: start at the configured budget; halve it
    // every time the next job arrives only after parking on the condvar
    // (dispatch inter-arrival outgrew the spin window), restore it the
    // moment a job lands without a park. An idle engine therefore decays
    // toward a tiny floor and parks almost immediately instead of
    // burning a core, while a hot repeated-solve loop keeps the full
    // spin. The floor (spin/16) keeps a small detection window alive so
    // traffic turning hot again can still land inside the spin phase and
    // restore the full budget — decaying all the way to 0 would be a
    // one-way ratchet (with no spin window, every arrival looks parked).
    let floor = shared.spin / 16;
    let mut budget = shared.spin;
    loop {
        // spin phase: cheap wakeup for back-to-back dispatches
        let mut spins = 0u32;
        while spins < budget && shared.epoch_hint.load(Ordering::Acquire) == seen {
            std::hint::spin_loop();
            spins += 1;
        }
        let mut parked = false;
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("job published with epoch");
                }
                parked = true;
                st = wait_ignore_poison(shared.cv_work.wait(st));
            }
        };
        if parked {
            let next = (budget / 2).max(floor);
            if next < budget {
                ctx.counters.spin_decays.fetch_add(1, Ordering::Relaxed);
            }
            budget = next;
        } else {
            budget = shared.spin;
        }
        // Safety: the dispatcher pins the job until `remaining` drops to 0.
        let r = catch_unwind(AssertUnwindSafe(|| {
            let f = unsafe { &*job.0 };
            f(id, &mut ctx);
        }));
        if r.is_err() {
            ctx.scrub_all();
        }
        let mut st = lock_ignore_poison(&shared.state);
        if r.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.cv_done.notify_all();
        }
    }
}

/// Reusable per-call solve arenas: permuted RHS, refinement buffers and
/// the multi-RHS blocks. One instance per concurrent in-flight `solve*`
/// call, checked out of the engine's [`ScratchPool`]; each grows to its
/// own high-water mark during warm-up and is reused verbatim afterwards.
#[derive(Default)]
pub struct SolveScratch {
    /// Permuted/scaled RHS in factor-row space (single RHS).
    pub y: Vec<f64>,
    /// Residual / correction RHS buffer.
    pub r: Vec<f64>,
    /// Correction solution buffer.
    pub d: Vec<f64>,
    /// Refinement candidate solution.
    pub x2: Vec<f64>,
    /// Dense n×k block for [`crate::coordinator::Solver::solve_many`].
    pub yk: Vec<f64>,
    /// Dense n×k residual block (`A·X`) for batched refinement.
    pub rk: Vec<f64>,
    /// Dense n×k refinement-candidate block.
    pub x2k: Vec<f64>,
}

/// Factor-side mutable engine state, exclusive for the duration of a
/// `factor`/`refactor` call (numeric factorization is exclusive by
/// nature: it rewrites the shared `LuFactors`).
#[derive(Default)]
pub struct FactorScratch {
    /// Cached permuted matrices, MRU-first, keyed by the owning analysis'
    /// unique id: `refactor` rewrites only the values in place instead of
    /// cloning O(nnz) per call (the coordinator caps the length).
    pub pa: Vec<(u64, Csr)>,
    /// Pipeline-mode done-flag arena, high-water sized to the largest
    /// analysis this engine has factored. Lives here — not in the shared
    /// `ExecPlan` — because it is mutable per-call state.
    pub done: crate::par::DoneFlags,
}

/// The persistent execution engine owned by a
/// [`crate::coordinator::Solver`]: one worker pool plus the coordinator
/// scratch (a checkout pool of solve arenas and the factor-side arenas),
/// sharing one counter block.
pub struct Engine {
    pool: WorkerPool,
    scratch: ScratchPool,
    factor_scratch: Mutex<FactorScratch>,
    counters: Arc<PoolCounters>,
}

impl Engine {
    /// Engine with `nthreads` workers, the given pre-park spin, and a
    /// solve-scratch checkout pool of `scratch_slots` instances
    /// (clamped to `1..=`[`MAX_SCRATCH_SLOTS`]).
    pub fn new(nthreads: usize, spin: u32, scratch_slots: usize) -> Self {
        let counters = Arc::new(PoolCounters::default());
        Engine {
            pool: WorkerPool::with_counters(nthreads, spin, counters.clone()),
            scratch: ScratchPool::new(scratch_slots),
            factor_scratch: Mutex::new(FactorScratch::default()),
            counters,
        }
    }

    /// The worker pool.
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Check one solve-scratch arena out of the pool (blocks while all
    /// slots are in flight; LIFO, so sequential callers always reuse the
    /// same warm slot). The slot returns to the pool when the guard
    /// drops.
    pub fn scratch(&self) -> ScratchGuard<'_> {
        self.scratch.checkout()
    }

    /// The scratch checkout pool (observability: capacity / in-use).
    pub fn scratch_pool(&self) -> &ScratchPool {
        &self.scratch
    }

    /// Lock the factor-side arenas (permuted-matrix MRU cache + pipeline
    /// done-flags). Poison-tolerant: a propagated job panic leaves the
    /// arenas consistent, see [`lock_ignore_poison`].
    pub fn factor_scratch(&self) -> MutexGuard<'_, FactorScratch> {
        lock_ignore_poison(&self.factor_scratch)
    }

    /// Shared counters.
    pub fn counters(&self) -> &Arc<PoolCounters> {
        &self.counters
    }

    /// OS threads spawned so far (0 until the first dispatch, then
    /// `nthreads - 1` forever).
    pub fn threads_spawned(&self) -> usize {
        self.counters.threads_spawned.load(Ordering::Relaxed)
    }

    /// Scratch-arena growth events so far.
    pub fn scratch_alloc_events(&self) -> u64 {
        self.counters.scratch_allocs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_every_worker_once_per_dispatch() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        for _ in 0..10 {
            pool.run(|| {}, |_, _| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 40);
        assert_eq!(pool.counters().threads_spawned.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn pool_of_one_runs_inline_and_spawns_nothing() {
        let pool = WorkerPool::new(1);
        let mut ran = false;
        pool.run(|| {}, |id, _| assert_eq!(id, 0));
        pool.run(|| ran = true, |_, _| {});
        assert!(ran);
        assert_eq!(pool.counters().threads_spawned.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pool_jobs_see_distinct_worker_ids() {
        let pool = WorkerPool::new(3);
        let seen: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|| {}, |id, _| {
            seen[id].fetch_add(1, Ordering::Relaxed);
        });
        for s in &seen {
            assert_eq!(s.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn pool_jobs_can_borrow_caller_stack() {
        let pool = WorkerPool::new(4);
        let data: Vec<usize> = (0..1000).collect();
        let partial: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|| {}, |id, _| {
            let chunk = data.len() / 4;
            let s: usize = data[id * chunk..(id + 1) * chunk].iter().sum();
            partial[id].store(s, Ordering::Relaxed);
        });
        let total: usize = partial.iter().map(|p| p.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn worker_workspaces_grow_once_then_stay() {
        let pool = WorkerPool::new(3);
        let c = pool.counters().clone();
        for _ in 0..5 {
            pool.run(|| {}, |_, ctx| {
                let ws = ctx.workspace(256, 64, 64, 16, 64, 64);
                assert!(ws.x.len() >= 256);
            });
        }
        let after_warm = c.scratch_allocs.load(Ordering::Relaxed);
        for _ in 0..5 {
            pool.run(|| {}, |_, ctx| {
                ctx.workspace(256, 64, 64, 16, 64, 64);
            });
        }
        assert_eq!(c.scratch_allocs.load(Ordering::Relaxed), after_warm);
    }

    #[test]
    fn setup_runs_before_workers_observe_job() {
        let pool = WorkerPool::new(4);
        let flag = AtomicUsize::new(0);
        pool.run(
            || flag.store(7, Ordering::Release),
            |_, _| assert_eq!(flag.load(Ordering::Acquire), 7),
        );
    }

    #[test]
    fn worker_panic_propagates_to_dispatcher() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(|| {}, |id, _| {
                if id == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // pool must still be usable afterwards
        let hits = AtomicUsize::new(0);
        pool.run(|| {}, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn engine_counters_are_shared() {
        let eng = Engine::new(2, 0, 2);
        assert_eq!(eng.threads_spawned(), 0, "no spawns before first dispatch");
        eng.pool().run(|| {}, |_, _| {});
        assert_eq!(eng.threads_spawned(), 1);
        let before = eng.scratch_alloc_events();
        ensure_len(&mut eng.scratch().y, 128, eng.counters());
        assert_eq!(eng.scratch_alloc_events(), before + 1);
        // LIFO checkout returns the same warm slot: no further growth
        ensure_len(&mut eng.scratch().y, 128, eng.counters());
        assert_eq!(eng.scratch_alloc_events(), before + 1);
    }

    #[test]
    fn pool_spawns_lazily_on_first_dispatch() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.counters().threads_spawned.load(Ordering::Relaxed), 0);
        pool.run(|| {}, |_, _| {});
        assert_eq!(pool.counters().threads_spawned.load(Ordering::Relaxed), 3);
        pool.run(|| {}, |_, _| {});
        assert_eq!(
            pool.counters().threads_spawned.load(Ordering::Relaxed),
            3,
            "spawn happens exactly once"
        );
    }

    #[test]
    fn worker_spin_decays_on_idle_gaps() {
        let pool = WorkerPool::with_counters(2, 512, Arc::new(PoolCounters::default()));
        pool.run(|| {}, |_, _| {});
        // 512 spin iterations elapse in far less than 20ms: the worker
        // parks, so the next dispatch arrives via the condvar and decays
        // the budget.
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.run(|| {}, |_, _| {});
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.run(|| {}, |_, _| {});
        assert!(
            pool.counters().spin_decays.load(Ordering::Relaxed) > 0,
            "idle gaps must decay the spin budget"
        );
    }

    #[test]
    fn engine_scratch_checkout_overlaps() {
        let eng = Engine::new(1, 0, 3);
        let g1 = eng.scratch();
        let g2 = eng.scratch();
        assert_eq!(eng.scratch_pool().in_use(), 2);
        drop(g1);
        drop(g2);
        assert_eq!(eng.scratch_pool().in_use(), 0);
    }
}

//! Stable C ABI over the [`crate::api`] handles (feature `ffi`).
//!
//! Mirrors upstream HYLU's C interface shape —
//! `Analyze / Factorize / ReFactorize / Solve / Free` on one opaque
//! handle — so cross-language callers and PARDISO-style drop-in
//! comparisons work against this reproduction. The Rust typestate
//! (`LinearSystem<Analyzed>` → `LinearSystem<Factored>`) degrades to a
//! runtime-checked state machine here: calling out of order returns
//! `HYLU_ERR_INVALID` instead of failing to compile.
//!
//! The authoritative C declarations live in `include/hylu.h`. Error
//! codes are [`crate::Error::code`] values (shared with the CLI exit
//! status); `0` is success and `1` is reserved for a caught Rust panic.
//!
//! Build: `cargo build --release --features ffi` produces
//! `libhylu.{so,dylib}` (the crate is also a `cdylib`).
//!
//! # Conventions
//!
//! - Matrices enter in CSR with 0-based `int64_t` indices: `ap` has
//!   `n + 1` row offsets starting at 0, `ai`/`ax` hold `ap[n]` column
//!   indices and values. Column indices must be strictly increasing
//!   within each row (use the MatrixMarket reader or a COO pre-pass to
//!   clean up arbitrary input).
//! - `hylu_refactorize`'s `ax` aligns element-for-element with the
//!   `ai`/`ax` arrays passed to `hylu_analyze` (same pattern, new
//!   values).
//! - Right-hand sides and solutions are dense `double` arrays of length
//!   `n`; `hylu_solve_many` packs `nrhs` of them column-after-column
//!   (`b + q*n`).
//! - Handles are **not thread-safe**: every entry point (including
//!   `hylu_solve`, which records failures in the handle's error slot)
//!   takes the handle exclusively — serialize all calls per handle, or
//!   use one handle per thread. Concurrent solving on shared factors is
//!   a Rust-API capability (`LinearSystem` is `Sync`), not an ABI one.
//! - A caught panic ([`HYLU_ERR_PANIC`]) in `analyze`/`factorize`/
//!   `refactorize` **poisons** the handle (factors may be inconsistent);
//!   subsequent calls fail with [`HYLU_ERR_INVALID`] until a fresh
//!   `hylu_analyze` resets it.

use std::ffi::CString;
use std::os::raw::c_char;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use crate::api::{Analyzed, Factored, LinearSystem, SolveOpts, Solver, SolverBuilder};
use crate::coordinator::Precision;
use crate::service::{Priority, ServiceConfig, SolverService, SystemId};
use crate::sparse::csr::Csr;
use crate::{Error, Result};

/// Success.
pub const HYLU_OK: i32 = 0;
/// A Rust panic was caught at the ABI boundary (internal bug).
pub const HYLU_ERR_PANIC: i32 = 1;
/// Invalid input or out-of-order call ([`Error::Invalid`]).
pub const HYLU_ERR_INVALID: i32 = 2;
/// I/O or parse failure ([`Error::Io`]).
pub const HYLU_ERR_IO: i32 = 3;
/// Structurally singular matrix ([`Error::StructurallySingular`]).
pub const HYLU_ERR_SINGULAR: i32 = 4;
/// Unperturbable zero pivot ([`Error::ZeroPivot`]).
pub const HYLU_ERR_ZERO_PIVOT: i32 = 5;
/// Runtime/backend failure ([`Error::Runtime`]).
pub const HYLU_ERR_RUNTIME: i32 = 6;
/// A service shard caught a panic while working on the request
/// ([`Error::ShardPanicked`]); the shard keeps serving.
pub const HYLU_ERR_SHARD_PANICKED: i32 = 7;
/// The request's deadline passed before dispatch
/// ([`Error::DeadlineExpired`]).
pub const HYLU_ERR_DEADLINE_EXPIRED: i32 = 8;
/// The target system is quarantined after a numeric or panic failure
/// ([`Error::Quarantined`]); the service retries recovery on later
/// refactorize/solve traffic.
pub const HYLU_ERR_QUARANTINED: i32 = 9;

enum SystemState {
    Empty,
    Analyzed(LinearSystem<Analyzed>),
    Factored(LinearSystem<Factored>),
    /// A panic was caught mid-mutation; factors may be half-written.
    /// Everything fails loudly until `hylu_analyze` rebuilds the state.
    Poisoned,
}

/// The opaque handle behind `hylu_handle` in `include/hylu.h`: one
/// solver (persistent engine) plus at most one linear system in one of
/// the lifecycle states, and the reusable solve buffers that keep the
/// warm repeated-solve loop allocation-free through the ABI too (after
/// the first solve of a given width, `hylu_solve`/`hylu_solve_many`
/// perform no heap allocation — only the unavoidable copies between the
/// caller's arrays and the engine's buffers).
pub struct HyluHandle {
    solver: Solver,
    state: SystemState,
    last_error: CString,
    /// Packed RHS buffers for `hylu_solve_many` (capacity reused).
    bs: Vec<Vec<f64>>,
    /// Solution buffers for `hylu_solve_many` (capacity reused).
    xs: Vec<Vec<f64>>,
    /// Single-RHS solution buffer (capacity reused).
    x1: Vec<f64>,
}

impl HyluHandle {
    fn fail(&mut self, e: &Error) -> i32 {
        self.last_error = CString::new(e.to_string()).unwrap_or_default();
        e.code()
    }

    fn invalid(&mut self, msg: &str) -> i32 {
        self.fail(&Error::Invalid(msg.into()))
    }
}

/// Run `f` with panic containment; a panic reports [`HYLU_ERR_PANIC`].
/// For handle-mutating entry points use [`guarded_mut`] instead, which
/// also poisons the handle.
fn guarded(f: impl FnOnce() -> i32) -> i32 {
    catch_unwind(AssertUnwindSafe(f)).unwrap_or(HYLU_ERR_PANIC)
}

/// [`guarded`] for read-only entry points on a handle (the solve path):
/// a caught panic leaves the factors untouched, so the handle stays
/// usable, but the message slot is updated so `hylu_last_error` never
/// reports a stale, unrelated failure.
fn guarded_note(h: &mut HyluHandle, f: impl FnOnce(&mut HyluHandle) -> i32) -> i32 {
    match catch_unwind(AssertUnwindSafe(|| f(&mut *h))) {
        Ok(code) => code,
        Err(_) => {
            h.last_error = CString::new("internal panic caught in solve; factors unchanged")
                .unwrap_or_default();
            HYLU_ERR_PANIC
        }
    }
}

/// [`guarded`] for entry points that mutate the system state: a caught
/// panic may have left factors half-written, so the handle is poisoned
/// (every later call fails with [`HYLU_ERR_INVALID`] until a fresh
/// `hylu_analyze`).
fn guarded_mut(h: &mut HyluHandle, f: impl FnOnce(&mut HyluHandle) -> i32) -> i32 {
    match catch_unwind(AssertUnwindSafe(|| f(&mut *h))) {
        Ok(code) => code,
        Err(_) => {
            h.state = SystemState::Poisoned;
            h.last_error =
                CString::new("internal panic caught; handle poisoned — call hylu_analyze to reset")
                    .unwrap_or_default();
            HYLU_ERR_PANIC
        }
    }
}

/// Build a validated CSR matrix from raw 0-based CSR arrays.
///
/// # Safety
/// `ap` must point to `n + 1` readable `i64`s; `ai` and `ax` must point
/// to `ap[n]` readable elements each.
unsafe fn csr_from_raw(n: i64, ap: *const i64, ai: *const i64, ax: *const f64) -> Result<Csr> {
    if n <= 0 {
        return Err(Error::Invalid(format!("n must be positive (got {n})")));
    }
    if ap.is_null() || ai.is_null() || ax.is_null() {
        return Err(Error::Invalid("ap/ai/ax must be non-null".into()));
    }
    let n = n as usize;
    let ap = std::slice::from_raw_parts(ap, n + 1);
    let mut indptr = Vec::with_capacity(n + 1);
    let mut prev = 0i64;
    for (i, &p) in ap.iter().enumerate() {
        if p < prev || (i == 0 && p != 0) {
            return Err(Error::Invalid(format!(
                "ap[{i}] = {p} is not a monotone 0-based row offset"
            )));
        }
        prev = p;
        indptr.push(p as usize);
    }
    let nnz = indptr[n];
    let ai = std::slice::from_raw_parts(ai, nnz);
    let ax = std::slice::from_raw_parts(ax, nnz);
    let mut indices = Vec::with_capacity(nnz);
    for (k, &j) in ai.iter().enumerate() {
        if j < 0 || j as usize >= n {
            return Err(Error::Invalid(format!(
                "ai[{k}] = {j} out of bounds for n={n} (indices are 0-based)"
            )));
        }
        indices.push(j as usize);
    }
    let a = Csr {
        n,
        indptr,
        indices,
        vals: ax.to_vec(),
    };
    a.validate().map_err(|e| {
        Error::Invalid(format!(
            "csr input rejected ({e}); column indices must be strictly increasing per row"
        ))
    })?;
    Ok(a)
}

/// Create a solver handle. `threads = 0` uses all cores; `repeated != 0`
/// selects the repeated-solve preset (relaxed supernodes, fast
/// refactorization). Writes the handle to `*out` and returns `HYLU_OK`.
///
/// # Safety
/// `out` must be a valid pointer to a `hylu_handle` slot. The returned
/// handle must be released with [`hylu_free`].
#[no_mangle]
pub unsafe extern "C" fn hylu_create(threads: i64, repeated: i32, out: *mut *mut HyluHandle) -> i32 {
    guarded(|| {
        if out.is_null() {
            return HYLU_ERR_INVALID;
        }
        if threads < 0 {
            return HYLU_ERR_INVALID;
        }
        let mut builder = SolverBuilder::new().threads(threads as usize);
        builder = if repeated != 0 {
            builder.repeated()
        } else {
            builder.one_shot()
        };
        // the ABI contract pins FFI handles to f64: HYLU_PRECISION must
        // not flip a C caller onto the mixed-precision path
        builder = builder.configure(|cfg| cfg.pin_precision = true);
        match builder.build() {
            Ok(solver) => {
                let h = Box::new(HyluHandle {
                    solver,
                    state: SystemState::Empty,
                    last_error: CString::default(),
                    bs: Vec::new(),
                    xs: Vec::new(),
                    x1: Vec::new(),
                });
                *out = Box::into_raw(h);
                HYLU_OK
            }
            // no handle exists yet to carry a message, but the stable
            // code still tells the caller what class of failure this was
            Err(e) => e.code(),
        }
    })
}

/// Analyze a CSR matrix (0-based indices, see the module docs for the
/// array contract). Replaces any previously analyzed/factorized system
/// on this handle.
///
/// # Safety
/// `h` must be a live handle from [`hylu_create`]; `ap` must point to
/// `n + 1` readable `int64_t`s and `ai`/`ax` to `ap[n]` readable
/// elements each.
#[no_mangle]
pub unsafe extern "C" fn hylu_analyze(
    h: *mut HyluHandle,
    n: i64,
    ap: *const i64,
    ai: *const i64,
    ax: *const f64,
) -> i32 {
    if h.is_null() {
        return HYLU_ERR_INVALID;
    }
    let h = &mut *h;
    guarded_mut(h, |h| {
        let a = match csr_from_raw(n, ap, ai, ax) {
            Ok(a) => a,
            Err(e) => return h.fail(&e),
        };
        match h.solver.analyze(a) {
            Ok(sys) => {
                h.state = SystemState::Analyzed(sys);
                HYLU_OK
            }
            Err(e) => h.fail(&e),
        }
    })
}

/// Numeric factorization with pivot search: `Analyzed → Factored`. On an
/// already-factored handle this re-runs the full factorization of the
/// current values (fresh pivot order).
///
/// # Safety
/// `h` must be a live handle from [`hylu_create`].
#[no_mangle]
pub unsafe extern "C" fn hylu_factorize(h: *mut HyluHandle) -> i32 {
    if h.is_null() {
        return HYLU_ERR_INVALID;
    }
    let h = &mut *h;
    guarded_mut(h, |h| {
        match std::mem::replace(&mut h.state, SystemState::Empty) {
            SystemState::Empty => h.invalid("hylu_factorize before hylu_analyze"),
            SystemState::Poisoned => {
                h.state = SystemState::Poisoned;
                h.invalid("handle poisoned by a caught panic; call hylu_analyze to reset")
            }
            SystemState::Analyzed(sys) => match sys.factor() {
                Ok(sys) => {
                    h.state = SystemState::Factored(sys);
                    HYLU_OK
                }
                Err(e) => h.fail(&e),
            },
            SystemState::Factored(mut sys) => {
                let r = sys.factorize();
                h.state = SystemState::Factored(sys);
                match r {
                    Ok(()) => HYLU_OK,
                    Err(e) => h.fail(&e),
                }
            }
        }
    })
}

/// Refactorize with new values on the stored pivot order (no pivot
/// search — the repeated-solve fast path). `ax` aligns with the arrays
/// passed to [`hylu_analyze`] and must hold `nnz` values.
///
/// # Safety
/// `h` must be a live, factorized handle; `ax` must point to `nnz`
/// readable doubles (`nnz` as returned by [`hylu_nnz`]).
#[no_mangle]
pub unsafe extern "C" fn hylu_refactorize(h: *mut HyluHandle, ax: *const f64) -> i32 {
    if h.is_null() {
        return HYLU_ERR_INVALID;
    }
    let h = &mut *h;
    guarded_mut(h, |h| {
        if ax.is_null() {
            return h.invalid("ax must be non-null");
        }
        let res = match &mut h.state {
            SystemState::Factored(sys) => {
                let vals = std::slice::from_raw_parts(ax, sys.nnz());
                sys.refactor(vals)
            }
            SystemState::Poisoned => {
                return h.invalid("handle poisoned by a caught panic; call hylu_analyze to reset")
            }
            _ => return h.invalid("hylu_refactorize before hylu_factorize"),
        };
        match res {
            Ok(()) => HYLU_OK,
            Err(e) => h.fail(&e),
        }
    })
}

/// Re-analyze with a matrix whose **pattern** may differ (dynamic-
/// topology step: circuit element stamped in or out). The warm
/// incremental path reuses the handle's engine, arenas, and ordering
/// seeds; an unchanged pattern also reuses the symbolic factorization
/// and tuned kernel plan outright, and a local pattern edit patches the
/// symbolic DAG incrementally (bit-identical to a cold analysis either
/// way). The system is refactorized on the new matrix before returning,
/// so the handle stays solvable; on failure the previous matrix and
/// factors are kept. Same CSR array contract as [`hylu_analyze`].
///
/// # Safety
/// `h` must be a live, factorized handle; `ap` must point to `n + 1`
/// readable `int64_t`s and `ai`/`ax` to `ap[n]` readable elements each.
#[no_mangle]
pub unsafe extern "C" fn hylu_reanalyze(
    h: *mut HyluHandle,
    n: i64,
    ap: *const i64,
    ai: *const i64,
    ax: *const f64,
) -> i32 {
    if h.is_null() {
        return HYLU_ERR_INVALID;
    }
    let h = &mut *h;
    guarded_mut(h, |h| {
        let a = match csr_from_raw(n, ap, ai, ax) {
            Ok(a) => a,
            Err(e) => return h.fail(&e),
        };
        let res = match &mut h.state {
            SystemState::Factored(sys) => sys.reanalyze_matrix(a),
            SystemState::Poisoned => {
                return h.invalid("handle poisoned by a caught panic; call hylu_analyze to reset")
            }
            _ => return h.invalid("hylu_reanalyze before hylu_factorize"),
        };
        match res {
            Ok(()) => HYLU_OK,
            Err(e) => h.fail(&e),
        }
    })
}

/// Solve `A x = b` (iterative refinement runs automatically when pivots
/// were perturbed). `b` and `x` are length-`n` arrays; they may not
/// alias.
///
/// # Safety
/// `h` must be a live, factorized handle; `b` must point to `n` readable
/// doubles and `x` to `n` writable doubles.
#[no_mangle]
pub unsafe extern "C" fn hylu_solve(h: *mut HyluHandle, b: *const f64, x: *mut f64) -> i32 {
    hylu_solve_many(h, 1, b, x)
}

/// Batched solve: `nrhs` right-hand sides packed column-after-column in
/// `b` (`b + q*n`), solutions written the same way into `x`. Column `q`
/// is bit-identical to a scalar [`hylu_solve`] of that column.
///
/// # Safety
/// `h` must be a live, factorized handle; `b` must point to `nrhs * n`
/// readable doubles and `x` to `nrhs * n` writable doubles.
#[no_mangle]
pub unsafe extern "C" fn hylu_solve_many(
    h: *mut HyluHandle,
    nrhs: i64,
    b: *const f64,
    x: *mut f64,
) -> i32 {
    if h.is_null() {
        return HYLU_ERR_INVALID;
    }
    let h = &mut *h;
    guarded_note(h, |h| {
        if nrhs <= 0 {
            return h.invalid("nrhs must be positive");
        }
        if b.is_null() || x.is_null() {
            return h.invalid("b/x must be non-null");
        }
        let k = nrhs as usize;
        let n = match &h.state {
            SystemState::Factored(sys) => sys.n(),
            SystemState::Poisoned => {
                return h.invalid("handle poisoned by a caught panic; call hylu_analyze to reset")
            }
            _ => return h.invalid("hylu_solve before hylu_factorize"),
        };
        let bin = std::slice::from_raw_parts(b, n * k);
        // the engine solves into the handle's reusable buffers: after
        // the first call of a given width this path is allocation-free
        let res = if k == 1 {
            let SystemState::Factored(sys) = &h.state else {
                unreachable!()
            };
            sys.solve_into(bin, &mut h.x1).map(|_| ())
        } else {
            h.bs.truncate(k);
            h.bs.resize_with(k, Vec::new);
            for (q, dst) in h.bs.iter_mut().enumerate() {
                dst.clear();
                dst.extend_from_slice(&bin[q * n..(q + 1) * n]);
            }
            let SystemState::Factored(sys) = &h.state else {
                unreachable!()
            };
            sys.solve_many_into(&h.bs, &mut h.xs).map(|_| ())
        };
        match res {
            Ok(()) => {
                let out = std::slice::from_raw_parts_mut(x, n * k);
                if k == 1 {
                    out.copy_from_slice(&h.x1);
                } else {
                    for (q, xq) in h.xs.iter().enumerate() {
                        out[q * n..(q + 1) * n].copy_from_slice(xq);
                    }
                }
                HYLU_OK
            }
            Err(e) => h.fail(&e),
        }
    })
}

/// Dimension of the analyzed system, or 0 when nothing is analyzed.
///
/// # Safety
/// `h` must be a live handle from [`hylu_create`] (or null, which
/// returns 0).
#[no_mangle]
pub unsafe extern "C" fn hylu_n(h: *const HyluHandle) -> i64 {
    if h.is_null() {
        return 0;
    }
    match &(*h).state {
        SystemState::Analyzed(sys) => sys.n() as i64,
        SystemState::Factored(sys) => sys.n() as i64,
        SystemState::Empty | SystemState::Poisoned => 0,
    }
}

/// Stored nonzeros of the analyzed system, or 0 when nothing is
/// analyzed.
///
/// # Safety
/// `h` must be a live handle from [`hylu_create`] (or null, which
/// returns 0).
#[no_mangle]
pub unsafe extern "C" fn hylu_nnz(h: *const HyluHandle) -> i64 {
    if h.is_null() {
        return 0;
    }
    match &(*h).state {
        SystemState::Analyzed(sys) => sys.nnz() as i64,
        SystemState::Factored(sys) => sys.nnz() as i64,
        SystemState::Empty | SystemState::Poisoned => 0,
    }
}

/// Message of the last error recorded on this handle (empty string when
/// none). The pointer is valid until the next failing call on the same
/// handle or [`hylu_free`].
///
/// # Safety
/// `h` must be a live handle from [`hylu_create`] (or null, which
/// returns an empty static string).
#[no_mangle]
pub unsafe extern "C" fn hylu_last_error(h: *const HyluHandle) -> *const c_char {
    if h.is_null() {
        static EMPTY: &[u8] = b"\0";
        return EMPTY.as_ptr() as *const c_char;
    }
    (*h).last_error.as_ptr()
}

/// Release a handle (idempotent for null). Joins nothing: the engine's
/// worker threads park and exit with the handle.
///
/// # Safety
/// `h` must be null or a live handle from [`hylu_create`]; it must not
/// be used afterwards.
#[no_mangle]
pub unsafe extern "C" fn hylu_free(h: *mut HyluHandle) {
    if !h.is_null() {
        drop(Box::from_raw(h));
    }
}

/// The opaque elastic-service handle behind `hylu_service` in
/// `include/hylu.h`: a sharded, coalescing
/// [`SolverService`](crate::service::SolverService) plus the solver used
/// to analyze+factor matrices entering through
/// [`hylu_service_register`], and the error slot. Mirrors the Rust
/// service's register/retire/rebalance lifecycle; like [`HyluHandle`],
/// the *handle* is not thread-safe (serialize calls per handle) even
/// though the underlying service is — concurrent submission is a
/// Rust-API capability.
pub struct HyluService {
    service: SolverService,
    solver: Solver,
    last_error: CString,
    /// Retained handles of retired systems are dropped immediately; this
    /// buffer only reuses the single-RHS solution allocation.
    x1: Vec<f64>,
}

impl HyluService {
    fn fail(&mut self, e: &Error) -> i32 {
        self.last_error = CString::new(e.to_string()).unwrap_or_default();
        e.code()
    }
}

/// Create an elastic solve service with `shards` dispatcher threads and
/// `threads` engine workers per registered system's solver (0 = all
/// cores). The service starts empty; admit systems with
/// [`hylu_service_register`]. Writes the handle to `*out`.
///
/// # Safety
/// `out` must be a valid pointer to a `hylu_service` slot. The returned
/// handle must be released with [`hylu_service_free`].
#[no_mangle]
pub unsafe extern "C" fn hylu_service_create(
    shards: i64,
    threads: i64,
    out: *mut *mut HyluService,
) -> i32 {
    guarded(|| {
        if out.is_null() || shards <= 0 || threads < 0 {
            return HYLU_ERR_INVALID;
        }
        let cfg = ServiceConfig {
            shards: shards as usize,
            ..ServiceConfig::default()
        };
        let solver = match SolverBuilder::new()
            .repeated()
            .threads(threads as usize)
            // same f64 pin as hylu_create: the service ABI is double too
            .configure(|cfg| cfg.pin_precision = true)
            .build()
        {
            Ok(s) => s,
            Err(e) => return e.code(),
        };
        match SolverService::with_shards(cfg) {
            Ok(service) => {
                let h = Box::new(HyluService {
                    service,
                    solver,
                    last_error: CString::default(),
                    x1: Vec::new(),
                });
                *out = Box::into_raw(h);
                HYLU_OK
            }
            Err(e) => e.code(),
        }
    })
}

/// Analyze + factorize a CSR matrix (same array contract as
/// [`hylu_analyze`]) and register it on the live service. Writes the
/// system id to `*out_id`; requests for retired ids fail with
/// [`HYLU_ERR_INVALID`] (ids are never reused).
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `ap` must
/// point to `n + 1` readable `int64_t`s, `ai`/`ax` to `ap[n]` readable
/// elements each, and `out_id` to a writable `uint64_t`.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_register(
    s: *mut HyluService,
    n: i64,
    ap: *const i64,
    ai: *const i64,
    ax: *const f64,
    out_id: *mut u64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        if out_id.is_null() {
            return s.fail(&Error::Invalid("out_id must be non-null".into()));
        }
        let a = match csr_from_raw(n, ap, ai, ax) {
            Ok(a) => a,
            Err(e) => return s.fail(&e),
        };
        let factored = match s.solver.analyze(a).and_then(|sys| sys.factor()) {
            Ok(f) => f,
            Err(e) => return s.fail(&e),
        };
        match s.service.register(factored) {
            Ok(id) => {
                *out_id = id.0;
                HYLU_OK
            }
            Err(e) => s.fail(&e),
        }
    })
}

/// Retire a system from the live service: queued solves for it drain
/// first, then its factor state is dropped.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`].
#[no_mangle]
pub unsafe extern "C" fn hylu_service_retire(s: *mut HyluService, id: u64) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| match s.service.retire(SystemId(id)) {
        Ok(_handle) => HYLU_OK, // dropping the handle releases its factors
        Err(e) => s.fail(&e),
    })
}

/// Per-call refinement overrides for `hylu_service_solve_opts`. Each
/// knob has an "unset" sentinel that falls back to the service solver's
/// configured default: negative for the numeric knobs, `0` for
/// `precision`.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct HyluSolveOpts {
    /// Refinement iteration cap; `< 0` = configured default, `0`
    /// disables refinement for this solve.
    pub refine_max_iter: i64,
    /// Residual above which refinement starts; `< 0` = default.
    pub refine_tol: f64,
    /// Residual target at which refinement stops; `< 0` = default.
    pub refine_target: f64,
    /// `0` = configured default, `1` = force `f64`, `2` = mixed
    /// (`f32` factors + `f64` refinement recovery).
    pub precision: i32,
}

impl HyluSolveOpts {
    fn to_opts(self) -> Result<SolveOpts> {
        let mut o = SolveOpts::new();
        if self.refine_max_iter >= 0 {
            o = o.refine_max_iter(self.refine_max_iter as usize);
        }
        if self.refine_tol >= 0.0 {
            o = o.refine_tol(self.refine_tol);
        }
        if self.refine_target >= 0.0 {
            o = o.refine_target(self.refine_target);
        }
        match self.precision {
            0 => {}
            1 => o = o.precision(Precision::F64),
            2 => o = o.precision(Precision::Mixed),
            p => {
                return Err(Error::Invalid(format!(
                    "unknown precision code {p} (0 = default, 1 = f64, 2 = mixed)"
                )))
            }
        }
        Ok(o)
    }
}

/// The shared single-RHS service solve: copy in, ride the queue on the
/// given lane with the given overrides, copy out.
///
/// # Safety
/// `b` must point to `n` readable doubles and `x` to `n` writable
/// doubles for system `id`'s dimension `n`.
unsafe fn service_solve_one(
    s: &mut HyluService,
    id: u64,
    b: *const f64,
    x: *mut f64,
    prio: Priority,
    opts: SolveOpts,
) -> i32 {
    if b.is_null() || x.is_null() {
        return s.fail(&Error::Invalid("b/x must be non-null".into()));
    }
    // the routing table owns the authoritative dimension
    let n = match s.service.system_dim(SystemId(id)) {
        Some(n) => n,
        None => return s.fail(&Error::Invalid(format!("unknown system id {id}"))),
    };
    let bin = std::slice::from_raw_parts(b, n);
    s.x1.clear();
    s.x1.extend_from_slice(bin);
    let rhs = std::mem::take(&mut s.x1);
    match s.service.solve_with_opts(SystemId(id), rhs, prio, opts) {
        Ok(sol) => {
            let out = std::slice::from_raw_parts_mut(x, n);
            out.copy_from_slice(&sol);
            s.x1 = sol; // keep the allocation warm
            HYLU_OK
        }
        Err(e) => s.fail(&e),
    }
}

/// Solve `A x = b` on system `id` through the coalescing queue
/// (blocking, bulk lane). `b` and `x` are length-`n` arrays for that
/// system's `n`.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `b` must
/// point to `n` readable doubles and `x` to `n` writable doubles.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_solve(
    s: *mut HyluService,
    id: u64,
    b: *const f64,
    x: *mut f64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        service_solve_one(s, id, b, x, Priority::Bulk, SolveOpts::default())
    })
}

/// [`hylu_service_solve`] on the deadline lane: the request dispatches
/// ahead of bulk traffic, earliest deadline first, where
/// `deadline_us` is the deadline relative to now in microseconds. When
/// the service expires deadlines, a request whose deadline passes
/// before dispatch fails with [`HYLU_ERR_DEADLINE_EXPIRED`] — and the
/// dispatcher's coalescing wait is clamped so an admitted-live request
/// is never expired by the shard's own sleep.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `b` must
/// point to `n` readable doubles and `x` to `n` writable doubles.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_solve_deadline(
    s: *mut HyluService,
    id: u64,
    b: *const f64,
    x: *mut f64,
    deadline_us: u64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        let at = Instant::now() + Duration::from_micros(deadline_us);
        service_solve_one(s, id, b, x, Priority::Deadline(at), SolveOpts::default())
    })
}

/// [`hylu_service_solve`] with per-call refinement overrides
/// ([`HyluSolveOpts`]); `opts` may be null for all-default. Requests
/// carrying different overrides are never coalesced into one block, so
/// an override cannot bleed into a neighboring caller's solve.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `b` must
/// point to `n` readable doubles, `x` to `n` writable doubles, and
/// `opts` must be null or point to a readable [`HyluSolveOpts`].
#[no_mangle]
pub unsafe extern "C" fn hylu_service_solve_opts(
    s: *mut HyluService,
    id: u64,
    b: *const f64,
    x: *mut f64,
    opts: *const HyluSolveOpts,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        let o = if opts.is_null() {
            SolveOpts::default()
        } else {
            match (*opts).to_opts() {
                Ok(o) => o,
                Err(e) => return s.fail(&e),
            }
        };
        service_solve_one(s, id, b, x, Priority::Bulk, o)
    })
}

/// Batched service solve: submit `nrhs` right-hand sides (packed
/// column-after-column in `b`, `b + q*n`) for system `id` in one call,
/// then block until all resolve, writing solutions the same way into
/// `x`. All requests are admitted before any is waited on, so they
/// coalesce into wide block dispatches. Column `q` is bit-identical to
/// a scalar [`hylu_service_solve`] of that column. On failure the first
/// error in submission order is returned; `x` columns whose requests
/// succeeded are still written.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `b` must
/// point to `nrhs * n` readable doubles and `x` to `nrhs * n` writable
/// doubles.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_solve_many(
    s: *mut HyluService,
    id: u64,
    nrhs: i64,
    b: *const f64,
    x: *mut f64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        if nrhs <= 0 {
            return s.fail(&Error::Invalid("nrhs must be positive".into()));
        }
        if b.is_null() || x.is_null() {
            return s.fail(&Error::Invalid("b/x must be non-null".into()));
        }
        let k = nrhs as usize;
        let n = match s.service.system_dim(SystemId(id)) {
            Some(n) => n,
            None => return s.fail(&Error::Invalid(format!("unknown system id {id}"))),
        };
        let bin = std::slice::from_raw_parts(b, n * k);
        let out = std::slice::from_raw_parts_mut(x, n * k);
        // submit everything first: the whole batch is in the queue
        // before the first wait, so one tick can drain it as one block
        let mut tickets = Vec::with_capacity(k);
        for q in 0..k {
            tickets.push(s.service.submit(SystemId(id), bin[q * n..(q + 1) * n].to_vec()));
        }
        let mut code = HYLU_OK;
        for (q, t) in tickets.into_iter().enumerate() {
            match t.and_then(|t| t.wait()) {
                Ok(sol) => out[q * n..(q + 1) * n].copy_from_slice(&sol),
                Err(e) => {
                    if code == HYLU_OK {
                        code = s.fail(&e);
                    }
                }
            }
        }
        code
    })
}

/// Rebalance hot systems across shards by observed load; writes the
/// number of systems moved to `*moved` (may be null).
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `moved` must
/// be null or point to a writable `int64_t`.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_rebalance(s: *mut HyluService, moved: *mut i64) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| match s.service.rebalance() {
        Ok(k) => {
            if !moved.is_null() {
                *moved = k as i64;
            }
            HYLU_OK
        }
        Err(e) => s.fail(&e),
    })
}

/// Health of a registered system: `0` = healthy, `1` = quarantined
/// after an unperturbable zero pivot, `2` = structurally singular
/// update, `3` = pivot growth over the configured limit, `4` = a caught
/// panic during factorization; `-1` = unknown id (never registered or
/// retired). Quarantined systems fail solves fast with
/// [`HYLU_ERR_QUARANTINED`] until a supervised full refactorization
/// restores them.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`] (or null,
/// which returns `-1`).
#[no_mangle]
pub unsafe extern "C" fn hylu_service_health(s: *const HyluService, id: u64) -> i32 {
    if s.is_null() {
        return -1;
    }
    match (*s).service.health(SystemId(id)) {
        Some(h) => h.encode() as i32,
        None => -1,
    }
}

/// Aggregate service counters for `hylu_service_stats` (a flat `repr(C)`
/// projection of the Rust `ServiceStats`, including shards already
/// drained by [`hylu_service_shrink`]).
#[repr(C)]
#[derive(Clone, Copy, Default)]
pub struct HyluServiceStats {
    /// Solve requests accepted.
    pub requests: u64,
    /// Subset of `requests` submitted on the deadline lane.
    pub deadline_requests: u64,
    /// Batched block dispatches issued.
    pub dispatches: u64,
    /// Right-hand sides solved across all dispatches.
    pub rhs_solved: u64,
    /// Refactorizations applied.
    pub refactors: u64,
    /// Live re-analyses applied.
    pub reanalyzes: u64,
    /// Requests re-routed between shards (routing-epoch staleness).
    pub forwarded: u64,
    /// Iterative-refinement rounds executed.
    pub refine_iters: u64,
    /// Systems registered over the service lifetime.
    pub registers: u64,
    /// Systems retired.
    pub retires: u64,
    /// Systems moved between shards (migrate / rebalance / shrink).
    pub moves: u64,
    /// Panics caught by shard supervision.
    pub panics_caught: u64,
    /// Healthy → quarantined transitions.
    pub quarantines: u64,
    /// Recovery attempts that restored a system to healthy.
    pub recoveries: u64,
    /// Deadline-lane requests expired before dispatch.
    pub expired: u64,
    /// Bulk requests rejected at admission by load shedding.
    pub shed: u64,
    /// Widest single batch dispatched.
    pub max_batch: u64,
    /// Mean right-hand sides per block dispatch (coalescing factor).
    pub mean_batch: f64,
    /// Widest coalescing wait any shard actually slept, in microseconds
    /// (the measured elapsed wait after preemption, not the requested
    /// window).
    pub max_tick_us: u64,
}

/// Snapshot the service's aggregate counters into `*out`.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `out` must
/// point to a writable [`HyluServiceStats`].
#[no_mangle]
pub unsafe extern "C" fn hylu_service_stats(
    s: *mut HyluService,
    out: *mut HyluServiceStats,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        if out.is_null() {
            return s.fail(&Error::Invalid("out must be non-null".into()));
        }
        let st = s.service.stats();
        *out = HyluServiceStats {
            requests: st.requests,
            deadline_requests: st.deadline_requests,
            dispatches: st.dispatches,
            rhs_solved: st.rhs_solved,
            refactors: st.refactors,
            reanalyzes: st.reanalyzes,
            forwarded: st.forwarded,
            refine_iters: st.refine_iters,
            registers: st.registers,
            retires: st.retires,
            moves: st.moves,
            panics_caught: st.panics_caught,
            quarantines: st.quarantines,
            recoveries: st.recoveries,
            expired: st.expired,
            shed: st.shed,
            max_batch: st.max_batch as u64,
            mean_batch: st.mean_batch(),
            max_tick_us: st.max_tick.as_micros() as u64,
        };
        HYLU_OK
    })
}

/// Grow the shard set by `k` dispatcher threads on the live service;
/// writes the new shard count to `*out_shards` (may be null). New
/// shards start empty — follow with [`hylu_service_rebalance`] to move
/// load onto them.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `out_shards`
/// must be null or point to a writable `int64_t`.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_grow(
    s: *mut HyluService,
    k: i64,
    out_shards: *mut i64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        if k < 0 {
            return s.fail(&Error::Invalid("k must be non-negative".into()));
        }
        match s.service.grow(k as usize) {
            Ok(n) => {
                if !out_shards.is_null() {
                    *out_shards = n as i64;
                }
                HYLU_OK
            }
            Err(e) => s.fail(&e),
        }
    })
}

/// Shrink the shard set by `k` dispatcher threads on the live service
/// (at least one must remain): resident systems migrate off the
/// draining shards, queued work drains, the threads join. Writes the
/// new shard count to `*out_shards` (may be null). No accepted request
/// is lost.
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`]; `out_shards`
/// must be null or point to a writable `int64_t`.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_shrink(
    s: *mut HyluService,
    k: i64,
    out_shards: *mut i64,
) -> i32 {
    if s.is_null() {
        return HYLU_ERR_INVALID;
    }
    let s = &mut *s;
    guarded_service(s, |s| {
        if k < 0 {
            return s.fail(&Error::Invalid("k must be non-negative".into()));
        }
        match s.service.shrink(k as usize) {
            Ok(n) => {
                if !out_shards.is_null() {
                    *out_shards = n as i64;
                }
                HYLU_OK
            }
            Err(e) => s.fail(&e),
        }
    })
}

/// Number of shard dispatcher threads currently running (0 for null).
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`] or null.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_shards(s: *const HyluService) -> i64 {
    if s.is_null() {
        return 0;
    }
    (*s).service.shard_count() as i64
}

/// Message of the last error recorded on this service handle (empty
/// string when none). The pointer is valid until the next failing call
/// on the same handle or [`hylu_service_free`].
///
/// # Safety
/// `s` must be a live handle from [`hylu_service_create`] (or null,
/// which returns an empty static string).
#[no_mangle]
pub unsafe extern "C" fn hylu_service_last_error(s: *const HyluService) -> *const c_char {
    if s.is_null() {
        static EMPTY: &[u8] = b"\0";
        return EMPTY.as_ptr() as *const c_char;
    }
    (*s).last_error.as_ptr()
}

/// Release a service handle (idempotent for null): queued work drains,
/// dispatcher threads join, every registered system's factors drop.
///
/// # Safety
/// `s` must be null or a live handle from [`hylu_service_create`]; it
/// must not be used afterwards.
#[no_mangle]
pub unsafe extern "C" fn hylu_service_free(s: *mut HyluService) {
    if !s.is_null() {
        drop(Box::from_raw(s));
    }
}

/// [`guarded`] for service entry points: a caught panic records a
/// message but does not poison — the service's own dispatchers contain
/// per-request failures, so the handle stays usable.
fn guarded_service(s: &mut HyluService, f: impl FnOnce(&mut HyluService) -> i32) -> i32 {
    match catch_unwind(AssertUnwindSafe(|| f(&mut *s))) {
        Ok(code) => code,
        Err(_) => {
            s.last_error = CString::new("internal panic caught at the service ABI boundary")
                .unwrap_or_default();
            HYLU_ERR_PANIC
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every `Error` variant must have a matching `HYLU_ERR_*` constant
    /// with the same value, and the reserved codes (`0` success, `1`
    /// panic) must never collide with a variant. The in-crate match has
    /// no wildcard arm, so adding an `Error` variant without extending
    /// the ABI constants fails to compile here before it can ship a
    /// code C callers can't name.
    #[test]
    fn ffi_error_consts_cover_every_error_variant() {
        let samples = [
            Error::Invalid(String::new()),
            Error::Io(String::new()),
            Error::StructurallySingular { matched: 0, n: 1 },
            Error::ZeroPivot { row: 0 },
            Error::Runtime(String::new()),
            Error::ShardPanicked { shard: 0 },
            Error::DeadlineExpired,
            Error::Quarantined(String::new()),
        ];
        for e in &samples {
            let expected = match e {
                Error::Invalid(_) => HYLU_ERR_INVALID,
                Error::Io(_) => HYLU_ERR_IO,
                Error::StructurallySingular { .. } => HYLU_ERR_SINGULAR,
                Error::ZeroPivot { .. } => HYLU_ERR_ZERO_PIVOT,
                Error::Runtime(_) => HYLU_ERR_RUNTIME,
                Error::ShardPanicked { .. } => HYLU_ERR_SHARD_PANICKED,
                Error::DeadlineExpired => HYLU_ERR_DEADLINE_EXPIRED,
                Error::Quarantined(_) => HYLU_ERR_QUARANTINED,
            };
            assert_eq!(e.code(), expected, "const mismatch for {e:?}");
            assert_ne!(e.code(), HYLU_OK, "code 0 is reserved for success");
            assert_ne!(
                e.code(),
                HYLU_ERR_PANIC,
                "code 1 is reserved for a caught panic at the ABI boundary"
            );
        }
        // pin the ABI values themselves: these are published in hylu.h
        // and must never be renumbered
        assert_eq!(
            [
                HYLU_OK,
                HYLU_ERR_PANIC,
                HYLU_ERR_INVALID,
                HYLU_ERR_IO,
                HYLU_ERR_SINGULAR,
                HYLU_ERR_ZERO_PIVOT,
                HYLU_ERR_RUNTIME,
                HYLU_ERR_SHARD_PANICKED,
                HYLU_ERR_DEADLINE_EXPIRED,
                HYLU_ERR_QUARANTINED,
            ],
            [0, 1, 2, 3, 4, 5, 6, 7, 8, 9]
        );
    }
}

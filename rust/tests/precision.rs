//! Mixed-precision policy acceptance tests.
//!
//! The contract under test (DESIGN.md "Precision policy"):
//!
//! - `Precision::Mixed` factors in `f32` and recovers double accuracy
//!   through `f64` iterative refinement — on well-conditioned systems the
//!   final residual must land within 10x of the pure-`f64` solve (or at
//!   the configured refinement target, whichever is looser).
//! - When refinement against the `f32` factors stalls above tolerance,
//!   the solve escalates deterministically: a full `f64` recovery
//!   factorization is built once, the fallback is latched and counted,
//!   and the fallback solve is **bitwise identical** to what a pure-`f64`
//!   solver produces (the recovery factors run the same fresh pivot
//!   search over the same remapped values).
//! - Repeated Mixed refactor+solve cycles over the same values are
//!   bitwise deterministic.
//! - `SolveOpts::precision(Precision::F64)` forces one solve onto the
//!   `f64` recovery factors without latching the handle-wide fallback.
//! - `RefineOutcome` telemetry is reported in pure-`f64` mode too.

use hylu::prelude::*;
use hylu::sparse::gen;

fn mixed_solver(threads: usize) -> Solver {
    SolverBuilder::new()
        .threads(threads)
        .precision(Precision::Mixed)
        .build()
        .unwrap()
}

fn f64_solver(threads: usize) -> Solver {
    SolverBuilder::new().threads(threads).build().unwrap()
}

#[test]
fn mixed_recovers_double_accuracy_on_well_conditioned_suite() {
    for a in [gen::grid2d(20, 20), gen::grid3d(7, 7, 7)] {
        let b = gen::rhs_for_ones(&a);

        let sys64 = f64_solver(2).analyze(&a).unwrap().factor().unwrap();
        let (x64, st64) = sys64.solve_with_stats(&b).unwrap();

        let sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
        assert_eq!(sys.precision(), Precision::Mixed);
        assert_eq!(sys.factor_stats().precision, Precision::Mixed);
        let (x, st) = sys.solve_with_stats(&b).unwrap();

        // no stall on a well-conditioned system: refinement recovers
        // double accuracy without ever touching the f64 recovery path
        assert_eq!(st.fallbacks, 0, "unexpected fallback (n={})", a.n);
        assert_eq!(sys.fallback_events(), 0);
        assert_eq!(st.precision, Precision::Mixed);
        assert_eq!(st.outcome, RefineOutcome::Converged);
        assert!(st.refine_iters >= 1, "f32 factors must need refinement");

        // the 10x acceptance window, floored at the refinement target
        // (a converged mixed solve can't be asked to beat the target the
        // f64 path undershoots for free)
        let floor = st64.residual.max(1e-14);
        assert!(
            st.residual <= 10.0 * floor,
            "mixed residual {:.3e} vs f64 {:.3e} (n={})",
            st.residual,
            st64.residual,
            a.n
        );
        let err = |xs: &[f64]| xs.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
        assert!(err(&x) <= 1e3 * err(&x64).max(1e-12));
    }
}

#[test]
fn mixed_falls_back_deterministically_on_ill_conditioned_fixture() {
    // cond ~1e14: refinement against f32 factors cannot contract the
    // residual (cond * eps_f32 >> 1), so the stall detector must fire
    let a = gen::ill_conditioned(300, 7);
    let b = gen::rhs_for_ones(&a);

    let sys64 = f64_solver(2).analyze(&a).unwrap().factor().unwrap();
    let (x64, st64) = sys64.solve_with_stats(&b).unwrap();

    let sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
    assert_eq!(sys.precision(), Precision::Mixed);
    let (x, st) = sys.solve_with_stats(&b).unwrap();

    // the stall escalated: event counted, handle latched onto f64
    assert_eq!(st.fallbacks, 1, "expected exactly one fallback event");
    assert_eq!(st.precision, Precision::F64);
    assert_eq!(sys.fallback_events(), 1);
    assert_eq!(sys.precision(), Precision::F64, "fallback must latch");

    // the recovery factors re-run the pure-f64 factorization (fresh
    // pivot search, same remapped values), so the fallback solve is
    // bitwise the pure-f64 solve — final-residual parity is exact
    assert_eq!(x, x64, "fallback solve must be bitwise the f64 solve");
    assert_eq!(st.residual.to_bits(), st64.residual.to_bits());

    // latched: the next solve skips the doomed mixed attempt, reuses the
    // recovery factors, counts nothing new, and stays bitwise stable
    let (x2, st2) = sys.solve_with_stats(&b).unwrap();
    assert_eq!(x2, x);
    assert_eq!(st2.fallbacks, 0);
    assert_eq!(st2.precision, Precision::F64);
    assert_eq!(sys.fallback_events(), 1);
}

#[test]
fn fallback_latch_promotes_the_next_refactor_to_f64() {
    let a = gen::ill_conditioned(300, 7);
    let b = gen::rhs_for_ones(&a);
    let mut sys = mixed_solver(1).analyze(&a).unwrap().factor().unwrap();
    sys.solve(&b).unwrap(); // stalls, latches
    assert_eq!(sys.precision(), Precision::F64);

    sys.refactor(&a.vals.clone()).unwrap();
    // the handle has permanently promoted: f32 factors are gone
    assert_eq!(sys.precision(), Precision::F64);
    assert_eq!(sys.factor_stats().precision, Precision::F64);

    // and the promoted handle now IS a pure-f64 solver, bitwise
    let sys64 = f64_solver(1).analyze(&a).unwrap().factor().unwrap();
    assert_eq!(sys.solve(&b).unwrap(), sys64.solve(&b).unwrap());
}

#[test]
fn mixed_refactor_solve_cycles_are_bitwise_deterministic() {
    let a = gen::grid2d(16, 16);
    let b = gen::rhs_for_ones(&a);
    let vals = a.vals.clone();
    let mut sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
    let x0 = sys.solve(&b).unwrap();
    for cycle in 0..3 {
        sys.refactor(&vals).unwrap();
        assert_eq!(sys.precision(), Precision::Mixed, "cycle {cycle}");
        let x = sys.solve(&b).unwrap();
        assert_eq!(x, x0, "cycle {cycle} diverged bitwise");
    }
    assert_eq!(sys.fallback_events(), 0);
}

#[test]
fn solve_opts_force_f64_without_latching_the_handle() {
    let a = gen::grid2d(20, 20);
    let b = gen::rhs_for_ones(&a);
    let sys64 = f64_solver(2).analyze(&a).unwrap().factor().unwrap();
    let (x64, _) = sys64.solve_with_stats(&b).unwrap();

    let sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
    let opts = SolveOpts::new().precision(Precision::F64);
    let (x, st) = sys.solve_with_opts(&b, &opts).unwrap();
    assert_eq!(st.precision, Precision::F64);
    assert_eq!(st.fallbacks, 0, "a forced f64 solve is not a fallback");
    assert_eq!(x, x64, "forced-f64 solve must be bitwise the f64 solve");

    // the handle itself stays mixed: no latch, no counted event
    assert_eq!(sys.precision(), Precision::Mixed);
    assert_eq!(sys.fallback_events(), 0);
    let (_, st2) = sys.solve_with_stats(&b).unwrap();
    assert_eq!(st2.precision, Precision::Mixed);

    // and Mixed as a per-call override is a no-op on a pure-f64 handle
    let opts = SolveOpts::new().precision(Precision::Mixed);
    let (_, st3) = sys64.solve_with_opts(&b, &opts).unwrap();
    assert_eq!(st3.precision, Precision::F64);
    assert_eq!(st3.fallbacks, 0);
}

#[test]
fn batched_mixed_solves_escalate_only_once() {
    let a = gen::ill_conditioned(300, 7);
    let b = gen::rhs_for_ones(&a);
    let bs: Vec<Vec<f64>> = (1..=3)
        .map(|q| b.iter().map(|v| v * q as f64).collect())
        .collect();

    let sys64 = f64_solver(2).analyze(&a).unwrap().factor().unwrap();
    let (xs64, _) = sys64.solve_many_with_stats(&bs).unwrap();

    let sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
    let (xs, st) = sys.solve_many_with_stats(&bs).unwrap();
    assert_eq!(st.fallbacks, 1, "one escalation covers the whole batch");
    assert_eq!(st.precision, Precision::F64);
    assert_eq!(sys.fallback_events(), 1);
    // every column stalled, so every column was re-solved against the
    // recovery factors — bitwise the pure-f64 batch
    assert_eq!(xs, xs64);
}

#[test]
fn batched_mixed_solves_stay_mixed_when_converged() {
    let a = gen::grid2d(20, 20);
    let b = gen::rhs_for_ones(&a);
    let bs: Vec<Vec<f64>> = (1..=4)
        .map(|q| b.iter().map(|v| v * q as f64).collect())
        .collect();
    let sys = mixed_solver(2).analyze(&a).unwrap().factor().unwrap();
    let (xs, st) = sys.solve_many_with_stats(&bs).unwrap();
    assert_eq!(st.fallbacks, 0);
    assert_eq!(st.precision, Precision::Mixed);
    assert_eq!(st.outcome, RefineOutcome::Converged);
    assert_eq!(sys.fallback_events(), 0);
    for (q, x) in xs.iter().enumerate() {
        let want = (q + 1) as f64;
        for v in x {
            assert!((v - want).abs() < 1e-6, "rhs {q}");
        }
    }
}

#[test]
fn refine_outcome_telemetry_reports_in_pure_f64_mode() {
    // a clean solve converges (possibly with zero iterations)
    let a = gen::grid2d(20, 20);
    let b = gen::rhs_for_ones(&a);
    let sys = f64_solver(1).analyze(&a).unwrap().factor().unwrap();
    let (_, st) = sys.solve_with_stats(&b).unwrap();
    assert_eq!(st.outcome, RefineOutcome::Converged);
    assert_eq!(st.precision, Precision::F64);
    assert_eq!(st.fallbacks, 0);

    // KKT saddle points perturb pivots, which forces refinement on; with
    // a zero iteration budget the loop must report the budget ran out
    // (unless raw substitution already met the target)
    let a = gen::kkt(150, 50, 3);
    let b = gen::rhs_for_ones(&a);
    let sys = f64_solver(1).analyze(&a).unwrap().factor().unwrap();
    assert!(sys.factor_stats().perturbed > 0, "fixture must perturb");
    let opts = SolveOpts::new().refine_max_iter(0);
    let (_, st) = sys.solve_with_opts(&b, &opts).unwrap();
    assert_eq!(st.refine_iters, 0);
    if st.residual > 1e-14 {
        assert_eq!(st.outcome, RefineOutcome::BudgetExhausted);
    } else {
        assert_eq!(st.outcome, RefineOutcome::Converged);
    }
}

#[test]
fn refine_outcome_worst_orders_severity() {
    use RefineOutcome::*;
    assert_eq!(Converged.worst(BudgetExhausted), BudgetExhausted);
    assert_eq!(BudgetExhausted.worst(Stalled), Stalled);
    assert_eq!(Stalled.worst(Converged), Stalled);
    assert_eq!(Converged.worst(Converged), Converged);
}

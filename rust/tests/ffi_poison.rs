//! Poisoned-handle recovery through the C ABI: a panic injected inside
//! `hylu_factorize` (via `HYLU_FAULT`) is caught at the boundary with
//! `HYLU_ERR_PANIC`, poisons the handle so every later call fails
//! loudly with `HYLU_ERR_INVALID`, and a fresh `hylu_analyze` fully
//! resets it. A panic injected in `hylu_solve` does NOT poison: the
//! factors are untouched, so the very next solve succeeds.
//!
//! `HYLU_FAULT` is process-global, so this scenario owns its test
//! binary (same isolation rationale as `probe_retier`); both phases run
//! inside one `#[test]` because the default parallel test runner would
//! otherwise race the variable. The variable is set only across the
//! `hylu_create` call that should absorb it (fault plans are sampled
//! once at solver construction) and removed immediately after.
//!
//! Built only with `--features ffi` (see `[[test]]` in Cargo.toml).

use std::ffi::CStr;

use hylu::ffi::{
    hylu_analyze, hylu_create, hylu_factorize, hylu_free, hylu_last_error, hylu_n, hylu_nnz,
    hylu_refactorize, hylu_solve, HyluHandle, HYLU_ERR_INVALID, HYLU_ERR_PANIC, HYLU_OK,
};
use hylu::prelude::*;
use hylu::sparse::gen;

/// A matrix in the raw arrays a C caller would hold.
struct RawCsr {
    n: i64,
    ap: Vec<i64>,
    ai: Vec<i64>,
    ax: Vec<f64>,
}

fn raw(a: &Csr) -> RawCsr {
    RawCsr {
        n: a.n as i64,
        ap: a.indptr.iter().map(|&p| p as i64).collect(),
        ai: a.indices.iter().map(|&j| j as i64).collect(),
        ax: a.vals.clone(),
    }
}

unsafe fn last_msg(h: *mut HyluHandle) -> String {
    CStr::from_ptr(hylu_last_error(h)).to_str().unwrap().to_string()
}

#[test]
fn injected_panics_poison_factor_but_not_solve_and_analyze_resets() {
    let a = gen::grid2d(10, 10);
    let b = gen::rhs_for_ones(&a);
    let m = raw(&a);

    unsafe {
        // ---- phase 1: panic during factorization poisons the handle ----
        // One injected factor panic (limit 1), then the plan is spent.
        std::env::set_var("HYLU_FAULT", "1:1:panic-factor:1");
        let mut h: *mut HyluHandle = std::ptr::null_mut();
        assert_eq!(hylu_create(1, 1, &mut h), HYLU_OK);
        std::env::remove_var("HYLU_FAULT");

        assert_eq!(
            hylu_analyze(h, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr()),
            HYLU_OK
        );
        assert_eq!(hylu_factorize(h), HYLU_ERR_PANIC);
        let msg = last_msg(h);
        assert!(msg.contains("poisoned"), "unhelpful message: {msg}");

        // everything fails loudly — but safely — until a reset
        assert_eq!(hylu_factorize(h), HYLU_ERR_INVALID);
        assert_eq!(hylu_refactorize(h, m.ax.as_ptr()), HYLU_ERR_INVALID);
        let mut x = vec![0.0f64; a.n];
        assert_eq!(hylu_solve(h, b.as_ptr(), x.as_mut_ptr()), HYLU_ERR_INVALID);
        let msg = last_msg(h);
        assert!(msg.contains("hylu_analyze"), "message must name the reset path: {msg}");
        assert_eq!(hylu_n(h), 0);
        assert_eq!(hylu_nnz(h), 0);

        // a fresh analyze rebuilds the state; the spent plan never fires
        // again, so the full lifecycle completes and solves correctly
        assert_eq!(
            hylu_analyze(h, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr()),
            HYLU_OK
        );
        assert_eq!(hylu_factorize(h), HYLU_OK);
        assert_eq!(hylu_solve(h, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
        hylu_free(h);

        // ---- phase 2: panic during solve leaves the handle serving ----
        std::env::set_var("HYLU_FAULT", "1:1:panic-solve:1");
        let mut h2: *mut HyluHandle = std::ptr::null_mut();
        assert_eq!(hylu_create(1, 1, &mut h2), HYLU_OK);
        std::env::remove_var("HYLU_FAULT");

        assert_eq!(
            hylu_analyze(h2, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr()),
            HYLU_OK
        );
        assert_eq!(hylu_factorize(h2), HYLU_OK);
        let mut x = vec![0.0f64; a.n];
        assert_eq!(hylu_solve(h2, b.as_ptr(), x.as_mut_ptr()), HYLU_ERR_PANIC);
        let msg = CStr::from_ptr(hylu_last_error(h2)).to_str().unwrap();
        assert!(msg.contains("factors unchanged"), "unhelpful message: {msg}");
        // factors untouched: the next solve (plan spent) succeeds
        assert_eq!(hylu_solve(h2, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
        hylu_free(h2);
    }
}

//! Deterministic service soak: client threads hammer solve/refactor on
//! stable systems while a chaos thread registers, solves, retires, and
//! rebalances systems on the same live service.
//!
//! Every completed solve is asserted **bit-identical** to a sequential
//! oracle:
//!
//! - the solver pipeline is deterministic, so an identically configured
//!   standalone handle produces the same analysis/factors;
//! - batched service columns are bit-identical to independent scalar
//!   solves (the engine's multi-RHS contract);
//! - refactor on the stored pivot order depends only on the current
//!   values, so the oracle can replay the same value history and record
//!   the expected solution per version.
//!
//! Each stable system has exactly one owner thread (the only submitter
//! for that id), so the owner always knows which value version its next
//! solve must observe — `refactor` blocks until applied and is a queue
//! barrier, making the per-system order deterministic even while the
//! chaos thread migrates the system between shards mid-traffic.
//!
//! Ticket accounting: submissions and completions are counted; every
//! accepted ticket resolves exactly once (mpsc gives at-most-once; the
//! counts give at-least-once). The final phase asserts clean drain on
//! drop.
//!
//! The shard count comes from `HYLU_TEST_SHARDS` when set (the CI
//! matrix runs {1, 4}); otherwise both are exercised in-process.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

const STABLE_SYSTEMS: usize = 4;
const VERSIONS: usize = 4; // value versions per stable system
const ROUNDS: usize = 24; // solves per owner thread
const CHAOS_CYCLES: usize = 12;

/// Per-system value history: version v scales the base values by
/// `1 + 0.25 * (s + 1) * v`-ish factors, deterministic per (s, v).
fn version_vals(base: &Csr, sys: usize, version: usize) -> Vec<f64> {
    let f = 1.0 + 0.2 * (sys + 1) as f64 + 0.35 * version as f64;
    base.vals.iter().map(|v| v * f).collect()
}

struct Oracle {
    /// expected[s][v] = bitwise-expected solution of system s at value
    /// version v for that system's fixed rhs.
    expected: Vec<Vec<Vec<f64>>>,
    rhs: Vec<Vec<f64>>,
}

/// Replay the exact value history each service system will live through
/// on identically configured standalone handles.
fn build_oracle(base: &Csr) -> Oracle {
    let mut rng = Prng::new(0xD5);
    let rhs: Vec<Vec<f64>> = (0..STABLE_SYSTEMS)
        .map(|_| (0..base.n).map(|_| rng.normal()).collect())
        .collect();
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let mut expected = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
        let mut per_version = Vec::with_capacity(VERSIONS);
        per_version.push(sys.solve(&rhs[s]).unwrap());
        for v in 1..VERSIONS {
            sys.refactor(&version_vals(base, s, v)).unwrap();
            per_version.push(sys.solve(&rhs[s]).unwrap());
        }
        expected.push(per_version);
    }
    Oracle { expected, rhs }
}

fn soak_cfg(shards: usize) -> ServiceConfig {
    ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        max_batch: 16,
        queue_cap: 1024,
        // adaptive window: stretches under the hammering, collapses when
        // a shard idles — the soak also covers the controller
        tick: Duration::from_micros(50),
        tick_max: Duration::from_millis(1),
        ..ServiceConfig::default()
    }
}

fn shard_counts() -> Vec<usize> {
    match std::env::var("HYLU_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("HYLU_TEST_SHARDS must be a number")],
        Err(_) => vec![1, 4],
    }
}

#[test]
fn soak_register_retire_rebalance_under_traffic() {
    let base = gen::power_network(220, 5);
    let oracle = build_oracle(&base);
    for shards in shard_counts() {
        soak_once(&base, &oracle, shards);
    }
}

fn soak_once(base: &Csr, oracle: &Oracle, shards: usize) {
    let service = SolverService::with_shards(soak_cfg(shards)).unwrap();
    // stable systems enter at version 0, one engine each (threads=1 so
    // dispatch is deterministic), ids recorded per slot
    let mut ids = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        let solver = SolverBuilder::new().threads(1).build().unwrap();
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        ids.push(service.register(sys).unwrap());
    }
    let submitted = AtomicU64::new(0);
    let completed = AtomicU64::new(0);

    std::thread::scope(|sc| {
        // owner threads: the ONLY submitters for their system, so each
        // solve's expected version is known exactly
        for s in 0..STABLE_SYSTEMS {
            let (service, oracle, ids) = (&service, oracle, &ids);
            let (submitted, completed) = (&submitted, &completed);
            sc.spawn(move || {
                let id = ids[s];
                let mut version = 0usize;
                for round in 0..ROUNDS {
                    // bump the value version at deterministic points
                    if round > 0 && round % (ROUNDS / VERSIONS) == 0 && version + 1 < VERSIONS {
                        version += 1;
                        let mut a = base.clone();
                        a.vals = version_vals(base, s, version);
                        service.refactor(id, a).unwrap();
                    }
                    // alternate lanes: deadline traffic must see the
                    // same bits as bulk traffic
                    let prio = if round % 3 == 0 {
                        Priority::Deadline(Instant::now() + Duration::from_micros(200))
                    } else {
                        Priority::Bulk
                    };
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let x = service
                        .solve_with(id, oracle.rhs[s].clone(), prio)
                        .unwrap();
                    completed.fetch_add(1, Ordering::Relaxed);
                    assert_eq!(
                        x, oracle.expected[s][version],
                        "system {s} round {round} version {version} (shards {shards})"
                    );
                }
            });
        }

        // chaos thread: live topology churn against the same service
        {
            let (service, ids) = (&service, &ids);
            sc.spawn(move || {
                let chaos_solver = SolverBuilder::new().threads(1).build().unwrap();
                let b = gen::rhs_for_ones(base);
                for cycle in 0..CHAOS_CYCLES {
                    // register a transient system, prove it serves
                    // bit-identically to its pre-registration self,
                    // then retire it and prove the value came back intact
                    let sys = chaos_solver.analyze(base).unwrap().factor().unwrap();
                    let expect = sys.solve(&b).unwrap();
                    let id = service.register(sys).unwrap();
                    assert_eq!(
                        service.solve(id, b.clone()).unwrap(),
                        expect,
                        "transient system, cycle {cycle}"
                    );
                    let back = service.retire(id).unwrap();
                    assert_eq!(back.solve(&b).unwrap(), expect, "retired handle, cycle {cycle}");

                    // bounce a stable system between shards mid-traffic
                    // and let the load balancer shuffle the rest
                    let victim = ids[cycle % STABLE_SYSTEMS];
                    service.migrate(victim, cycle % shards).unwrap();
                    service.rebalance().unwrap();

                    // a retired id must stay dead
                    assert!(service.submit(id, b.clone()).is_err(), "retired id rejected");
                }
            });
        }
    });

    // no lost or double-completed tickets
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        completed.load(Ordering::Relaxed),
        "every accepted ticket resolves exactly once"
    );
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        (STABLE_SYSTEMS * ROUNDS) as u64
    );

    let st = service.stats();
    assert!(
        st.rhs_solved >= (STABLE_SYSTEMS * ROUNDS) as u64,
        "owner traffic plus chaos solves all dispatched"
    );
    assert_eq!(st.registers as usize, STABLE_SYSTEMS + CHAOS_CYCLES);
    assert_eq!(st.retires as usize, CHAOS_CYCLES);
    assert!(
        st.max_tick <= Duration::from_millis(1),
        "adaptive window {:?} within tick_max",
        st.max_tick
    );
    // the routing epoch advanced once per topology change at least
    assert!(service.route_epoch() >= 1 + STABLE_SYSTEMS + 2 * CHAOS_CYCLES);

    // clean drain on drop: a burst left in the queue resolves after the
    // service value is gone
    let burst: Vec<_> = (0..10)
        .map(|_| service.submit(ids[0], oracle.rhs[0].clone()).unwrap())
        .collect();
    drop(service);
    for t in burst {
        let x = t.wait().unwrap();
        assert_eq!(x, oracle.expected[0][VERSIONS - 1], "drained after drop");
    }
}

//! Deterministic service soak: client threads hammer solve/refactor on
//! stable systems while a chaos thread registers, solves, retires, and
//! rebalances systems on the same live service.
//!
//! Every completed solve is asserted **bit-identical** to a sequential
//! oracle:
//!
//! - the solver pipeline is deterministic, so an identically configured
//!   standalone handle produces the same analysis/factors;
//! - batched service columns are bit-identical to independent scalar
//!   solves (the engine's multi-RHS contract);
//! - refactor on the stored pivot order depends only on the current
//!   values, so the oracle can replay the same value history and record
//!   the expected solution per version.
//!
//! Each stable system has exactly one owner thread (the only submitter
//! for that id), so the owner always knows which value version its next
//! solve must observe — `refactor` blocks until applied and is a queue
//! barrier, making the per-system order deterministic even while the
//! chaos thread migrates the system between shards mid-traffic.
//!
//! Ticket accounting: submissions and completions are counted; every
//! accepted ticket resolves exactly once (mpsc gives at-most-once; the
//! counts give at-least-once). The final phase asserts clean drain on
//! drop.
//!
//! The shard count comes from `HYLU_TEST_SHARDS` when set (the CI
//! matrix runs {1, 4}); otherwise both are exercised in-process.
//!
//! The **chaos leg** re-runs the soak shape under a deterministic
//! [`FaultPlan`] (the `HYLU_FAULT` env plan when set — the CI chaos
//! job — otherwise a built-in panic/zero-pivot mix): dispatchers absorb
//! injected panics, failed refactors quarantine their system, owners
//! retry until the escalated full-pivot recovery restores it, and every
//! served solution is asserted bitwise against a *multi-candidate*
//! oracle — the pure refactor chain plus every chain restarted by a
//! full re-pivot recovery at some earlier version (recovery refactors
//! the current values from a fresh pivot search, so later refactors
//! continue from that pivot order). The clean soak's oracle and system
//! solvers are `pin_fault()`-ed so both legs run under a chaos
//! environment.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;
use hylu::Error;

const STABLE_SYSTEMS: usize = 4;
const VERSIONS: usize = 4; // value versions per stable system
const ROUNDS: usize = 24; // solves per owner thread
const CHAOS_CYCLES: usize = 12;

/// Per-system value history: version v scales the base values by
/// `1 + 0.25 * (s + 1) * v`-ish factors, deterministic per (s, v).
fn version_vals(base: &Csr, sys: usize, version: usize) -> Vec<f64> {
    let f = 1.0 + 0.2 * (sys + 1) as f64 + 0.35 * version as f64;
    base.vals.iter().map(|v| v * f).collect()
}

struct Oracle {
    /// expected[s][v] = bitwise-expected solution of system s at value
    /// version v for that system's fixed rhs.
    expected: Vec<Vec<Vec<f64>>>,
    rhs: Vec<Vec<f64>>,
}

/// Replay the exact value history each service system will live through
/// on identically configured standalone handles.
fn build_oracle(base: &Csr) -> Oracle {
    let mut rng = Prng::new(0xD5);
    let rhs: Vec<Vec<f64>> = (0..STABLE_SYSTEMS)
        .map(|_| (0..base.n).map(|_| rng.normal()).collect())
        .collect();
    // pinned: the oracle must stay fault-free under a chaos environment
    let solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
    let mut expected = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
        let mut per_version = Vec::with_capacity(VERSIONS);
        per_version.push(sys.solve(&rhs[s]).unwrap());
        for v in 1..VERSIONS {
            sys.refactor(&version_vals(base, s, v)).unwrap();
            per_version.push(sys.solve(&rhs[s]).unwrap());
        }
        expected.push(per_version);
    }
    Oracle { expected, rhs }
}

fn soak_cfg(shards: usize) -> ServiceConfig {
    ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        max_batch: 16,
        queue_cap: 1024,
        // adaptive window: stretches under the hammering, collapses when
        // a shard idles — the soak also covers the controller
        tick: Duration::from_micros(50),
        tick_max: Duration::from_millis(1),
        ..ServiceConfig::default()
    }
}

fn shard_counts() -> Vec<usize> {
    match std::env::var("HYLU_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("HYLU_TEST_SHARDS must be a number")],
        Err(_) => vec![1, 4],
    }
}

#[test]
fn soak_register_retire_rebalance_under_traffic() {
    let base = gen::power_network(220, 5);
    let oracle = build_oracle(&base);
    for shards in shard_counts() {
        soak_once(&base, &oracle, shards);
    }
}

fn soak_once(base: &Csr, oracle: &Oracle, shards: usize) {
    let service = SolverService::with_shards(soak_cfg(shards)).unwrap();
    // stable systems enter at version 0, one engine each (threads=1 so
    // dispatch is deterministic), ids recorded per slot
    let mut ids = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        // pinned: the clean soak asserts exact bits, so an HYLU_FAULT
        // env plan (the CI chaos job) must not reach these systems
        let solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        ids.push(service.register(sys).unwrap());
    }
    let submitted = AtomicU64::new(0);
    let completed = AtomicU64::new(0);

    std::thread::scope(|sc| {
        // owner threads: the ONLY submitters for their system, so each
        // solve's expected version is known exactly
        for s in 0..STABLE_SYSTEMS {
            let (service, oracle, ids) = (&service, oracle, &ids);
            let (submitted, completed) = (&submitted, &completed);
            sc.spawn(move || {
                let id = ids[s];
                let mut version = 0usize;
                for round in 0..ROUNDS {
                    // bump the value version at deterministic points
                    if round > 0 && round % (ROUNDS / VERSIONS) == 0 && version + 1 < VERSIONS {
                        version += 1;
                        let mut a = base.clone();
                        a.vals = version_vals(base, s, version);
                        service.refactor(id, a).unwrap();
                    }
                    // alternate lanes: deadline traffic must see the
                    // same bits as bulk traffic
                    let prio = if round % 3 == 0 {
                        Priority::Deadline(Instant::now() + Duration::from_micros(200))
                    } else {
                        Priority::Bulk
                    };
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let x = service
                        .solve_with(id, oracle.rhs[s].clone(), prio)
                        .unwrap();
                    completed.fetch_add(1, Ordering::Relaxed);
                    assert_eq!(
                        x, oracle.expected[s][version],
                        "system {s} round {round} version {version} (shards {shards})"
                    );
                }
            });
        }

        // chaos thread: live topology churn against the same service
        {
            let (service, ids) = (&service, &ids);
            sc.spawn(move || {
                let chaos_solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
                let b = gen::rhs_for_ones(base);
                for cycle in 0..CHAOS_CYCLES {
                    // register a transient system, prove it serves
                    // bit-identically to its pre-registration self,
                    // then retire it and prove the value came back intact
                    let sys = chaos_solver.analyze(base).unwrap().factor().unwrap();
                    let expect = sys.solve(&b).unwrap();
                    let id = service.register(sys).unwrap();
                    assert_eq!(
                        service.solve(id, b.clone()).unwrap(),
                        expect,
                        "transient system, cycle {cycle}"
                    );
                    let back = service.retire(id).unwrap();
                    assert_eq!(back.solve(&b).unwrap(), expect, "retired handle, cycle {cycle}");

                    // bounce a stable system between shards mid-traffic
                    // and let the load balancer shuffle the rest
                    let victim = ids[cycle % STABLE_SYSTEMS];
                    service.migrate(victim, cycle % shards).unwrap();
                    service.rebalance().unwrap();

                    // a retired id must stay dead
                    assert!(service.submit(id, b.clone()).is_err(), "retired id rejected");
                }
            });
        }
    });

    // no lost or double-completed tickets
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        completed.load(Ordering::Relaxed),
        "every accepted ticket resolves exactly once"
    );
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        (STABLE_SYSTEMS * ROUNDS) as u64
    );

    let st = service.stats();
    assert!(
        st.rhs_solved >= (STABLE_SYSTEMS * ROUNDS) as u64,
        "owner traffic plus chaos solves all dispatched"
    );
    assert_eq!(st.registers as usize, STABLE_SYSTEMS + CHAOS_CYCLES);
    assert_eq!(st.retires as usize, CHAOS_CYCLES);
    assert!(
        st.max_tick <= Duration::from_millis(1),
        "adaptive window {:?} within tick_max",
        st.max_tick
    );
    // the routing epoch advanced once per topology change at least
    assert!(service.route_epoch() >= 1 + STABLE_SYSTEMS + 2 * CHAOS_CYCLES);

    // clean drain on drop: a burst left in the queue resolves after the
    // service value is gone
    let burst: Vec<_> = (0..10)
        .map(|_| service.submit(ids[0], oracle.rhs[0].clone()).unwrap())
        .collect();
    drop(service);
    for t in burst {
        let x = t.wait().unwrap();
        assert_eq!(x, oracle.expected[0][VERSIONS - 1], "drained after drop");
    }
}

// ---------------------------------------------------------------------
// Elastic leg: the same soak shape while the shard SET itself breathes.
// ---------------------------------------------------------------------

/// Live grow/shrink under traffic: owner threads hammer their systems
/// (bit-identity against the sequential oracle, exact ticket accounting)
/// while a breather thread repeatedly stretches the shard set from the
/// base width to `base + 3` — rebalancing load onto each new shard — and
/// drains it back down. Every transition must preserve:
///
/// - bit-identity: served solutions equal the oracle's at every version;
/// - ticket accounting: zero lost or double-completed tickets, through
///   queue drains, forwards, and dispatcher joins;
/// - routing-epoch monotonicity: each topology publication advances the
///   shard epoch, and a settled service answers from the base width.
#[test]
fn soak_live_grow_shrink_under_traffic() {
    let base = gen::power_network(220, 5);
    let oracle = build_oracle(&base);
    for shards in shard_counts() {
        elastic_once(&base, &oracle, shards);
    }
}

fn elastic_once(base: &Csr, oracle: &Oracle, shards: usize) {
    let service = SolverService::with_shards(soak_cfg(shards)).unwrap();
    let mut ids = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        let solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        ids.push(service.register(sys).unwrap());
    }
    let submitted = AtomicU64::new(0);
    let completed = AtomicU64::new(0);
    let stop = std::sync::atomic::AtomicBool::new(false);
    let grow_to = shards + 3;
    let epoch0 = service.shard_epoch();

    std::thread::scope(|sc| {
        // owner threads: identical to the clean soak — refactor barriers
        // and solves whose expected bits are known exactly per version
        for s in 0..STABLE_SYSTEMS {
            let (service, oracle, ids) = (&service, oracle, &ids);
            let (submitted, completed) = (&submitted, &completed);
            sc.spawn(move || {
                let id = ids[s];
                let mut version = 0usize;
                for round in 0..ROUNDS {
                    if round > 0 && round % (ROUNDS / VERSIONS) == 0 && version + 1 < VERSIONS {
                        version += 1;
                        let mut a = base.clone();
                        a.vals = version_vals(base, s, version);
                        service.refactor(id, a).unwrap();
                    }
                    let prio = if round % 3 == 0 {
                        Priority::Deadline(Instant::now() + Duration::from_micros(200))
                    } else {
                        Priority::Bulk
                    };
                    submitted.fetch_add(1, Ordering::Relaxed);
                    let x = service
                        .solve_with(id, oracle.rhs[s].clone(), prio)
                        .unwrap();
                    completed.fetch_add(1, Ordering::Relaxed);
                    assert_eq!(
                        x, oracle.expected[s][version],
                        "system {s} round {round} version {version} \
                         (base {shards} shards, breathing to {grow_to})"
                    );
                }
            });
        }

        // breather thread: stretch the shard set one dispatcher at a
        // time up to `grow_to`, rebalancing load onto each new shard,
        // then drain back to the base — repeatedly, mid-traffic
        {
            let (service, stop) = (&service, &stop);
            sc.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    while service.shard_count() < grow_to && !stop.load(Ordering::Relaxed) {
                        service.grow(1).unwrap();
                        service.rebalance().unwrap();
                        std::thread::sleep(Duration::from_micros(300));
                    }
                    while service.shard_count() > shards && !stop.load(Ordering::Relaxed) {
                        service.shrink(1).unwrap();
                        std::thread::sleep(Duration::from_micros(300));
                    }
                }
                // settle: the service must end at the base width with
                // every system drained onto a surviving shard
                while service.shard_count() > shards {
                    service.shrink(1).unwrap();
                }
            });
            // owners finishing flips the stop flag for the breather
        }
        sc.spawn(|| {
            // watchdog: wait for the owners by ticket count, then stop
            // the breather (scope joins everything)
            while completed.load(Ordering::Relaxed) < (STABLE_SYSTEMS * ROUNDS) as u64 {
                std::thread::sleep(Duration::from_micros(500));
            }
            stop.store(true, Ordering::Relaxed);
        });
    });

    // zero lost or double-completed tickets through every transition
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        completed.load(Ordering::Relaxed),
        "every accepted ticket resolves exactly once (base {shards})"
    );
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        (STABLE_SYSTEMS * ROUNDS) as u64
    );
    assert_eq!(service.shard_count(), shards, "settled at the base width");
    assert!(
        service.shard_epoch() > epoch0,
        "topology churn advanced the shard epoch"
    );
    for (s, id) in ids.iter().enumerate() {
        assert!(
            matches!(service.health(*id), Some(Health::Healthy)),
            "system {s} healthy after the drains"
        );
        assert_eq!(
            service.solve(*id, oracle.rhs[s].clone()).unwrap(),
            oracle.expected[s][VERSIONS - 1],
            "system {s} answers from the settled set"
        );
    }
    let st = service.stats();
    assert!(
        st.rhs_solved >= (STABLE_SYSTEMS * ROUNDS) as u64,
        "all owner traffic dispatched, including across drains"
    );
    assert_eq!(st.registers as usize, STABLE_SYSTEMS);
    drop(service);
}

// ---------------------------------------------------------------------
// Chaos leg: the same soak shape under deterministic fault injection.
// ---------------------------------------------------------------------

/// All bitwise-legal solutions per `(system, version)` under fault
/// recovery. `candidates[s][v]` holds the pure refactor-chain solution
/// plus the solution of every chain restarted by a recovery — a full
/// re-pivot factorization of the version-`p` values for some `p <= v`,
/// after which later refactors continue from that fresh pivot order.
/// Both the initial `factor()` and the recovery `factorize()` are full
/// pivot-searching factorizations of (analysis, current values), so the
/// state after any *sequence* of recoveries collapses to the chain
/// restarted at the last one — the candidate set is complete.
struct ChaosOracle {
    candidates: Vec<Vec<Vec<Vec<f64>>>>,
    rhs: Vec<Vec<f64>>,
}

fn push_unique(set: &mut Vec<Vec<f64>>, x: Vec<f64>) {
    if !set.iter().any(|e| e == &x) {
        set.push(x);
    }
}

fn build_chaos_oracle(base: &Csr) -> ChaosOracle {
    let mut rng = Prng::new(0xC4);
    let rhs: Vec<Vec<f64>> = (0..STABLE_SYSTEMS)
        .map(|_| (0..base.n).map(|_| rng.normal()).collect())
        .collect();
    // pinned: the oracle must stay fault-free under a chaos environment
    let solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
    let mut candidates = vec![vec![Vec::new(); VERSIONS]; STABLE_SYSTEMS];
    for s in 0..STABLE_SYSTEMS {
        let mut a0 = base.clone();
        a0.vals = version_vals(base, s, 0);
        // the pure refactor chain (no recovery ever fired)
        let mut sys = solver.analyze(&a0).unwrap().factor().unwrap();
        push_unique(&mut candidates[s][0], sys.solve(&rhs[s]).unwrap());
        for v in 1..VERSIONS {
            sys.refactor(&version_vals(base, s, v)).unwrap();
            push_unique(&mut candidates[s][v], sys.solve(&rhs[s]).unwrap());
        }
        // chains restarted by a recovery escalation at version p
        for p in 0..VERSIONS {
            let mut sys = solver.analyze(&a0).unwrap().factor().unwrap();
            for v in 1..=p {
                sys.refactor(&version_vals(base, s, v)).unwrap();
            }
            sys.factorize().unwrap();
            push_unique(&mut candidates[s][p], sys.solve(&rhs[s]).unwrap());
            for v in (p + 1)..VERSIONS {
                sys.refactor(&version_vals(base, s, v)).unwrap();
                push_unique(&mut candidates[s][v], sys.solve(&rhs[s]).unwrap());
            }
        }
    }
    ChaosOracle { candidates, rhs }
}

#[test]
fn chaos_soak_supervision_quarantine_recovery() {
    let base = gen::power_network(220, 5);
    let oracle = build_chaos_oracle(&base);
    // The HYLU_FAULT plan (the CI chaos matrix) wins; otherwise a
    // built-in panic/zero-pivot mix. Period 5 clears the 4 registration
    // factorizations, which run on the test thread outside shard
    // supervision (and registration retries through faults regardless).
    let plan = FaultPlan::from_env().unwrap_or_else(|| {
        Arc::new(FaultPlan::new(
            42,
            5,
            vec![Fault::PanicInFactor, Fault::PanicInSolve, Fault::ForceZeroPivot],
        ))
    });
    for shards in shard_counts() {
        chaos_once(&base, &oracle, shards, &plan);
    }
}

fn chaos_once(base: &Csr, oracle: &ChaosOracle, shards: usize, plan: &Arc<FaultPlan>) {
    let mut cfg = soak_cfg(shards);
    cfg.expire_deadlines = true;
    let service = SolverService::with_shards(cfg).unwrap();
    let mut ids = Vec::with_capacity(STABLE_SYSTEMS);
    for s in 0..STABLE_SYSTEMS {
        let solver = SolverBuilder::new()
            .threads(1)
            .fault(plan.clone())
            .build()
            .unwrap();
        let mut a = base.clone();
        a.vals = version_vals(base, s, 0);
        // registration factors run here, outside shard supervision:
        // contain and retry whatever the plan fires at these steps
        let mut tries = 0;
        let sys = loop {
            let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                solver.analyze(&a).and_then(|sys| sys.factor())
            }));
            match attempt {
                Ok(Ok(sys)) => break sys,
                _ => {
                    tries += 1;
                    assert!(tries < 200, "registration never cleared the fault plan");
                }
            }
        };
        ids.push(service.register(sys).unwrap());
    }

    let submitted = AtomicU64::new(0);
    let completed = AtomicU64::new(0);
    let failed = AtomicU64::new(0);

    std::thread::scope(|sc| {
        for s in 0..STABLE_SYSTEMS {
            let (service, oracle, ids) = (&service, oracle, &ids);
            let (submitted, completed, failed) = (&submitted, &completed, &failed);
            sc.spawn(move || {
                let id = ids[s];
                let mut version = 0usize;
                for round in 0..ROUNDS {
                    if round > 0 && round % (ROUNDS / VERSIONS) == 0 && version + 1 < VERSIONS {
                        // the version advances ONLY on refactor Ok: a
                        // failed attempt (injected zero pivot / panic,
                        // or fail-fast while quarantined) leaves the
                        // previous values resident
                        let mut tries = 0;
                        loop {
                            let mut a = base.clone();
                            a.vals = version_vals(base, s, version + 1);
                            if service.refactor(id, a).is_ok() {
                                break;
                            }
                            tries += 1;
                            assert!(tries < 500, "system {s} refactor never recovered");
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        version += 1;
                    }
                    let prio = if round % 3 == 0 {
                        Priority::Deadline(Instant::now() + Duration::from_millis(50))
                    } else {
                        Priority::Bulk
                    };
                    // ride through injected failures: every ticket still
                    // resolves exactly once (counted), and retries keep
                    // soliciting the shard until supervision + escalated
                    // recovery let the solve through again
                    let mut tries = 0;
                    let x = loop {
                        submitted.fetch_add(1, Ordering::Relaxed);
                        match service.solve_with(id, oracle.rhs[s].clone(), prio) {
                            Ok(x) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                                break x;
                            }
                            Err(_) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                                failed.fetch_add(1, Ordering::Relaxed);
                                tries += 1;
                                assert!(tries < 500, "system {s} solve never recovered");
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                    };
                    assert!(
                        oracle.candidates[s][version].iter().any(|e| e == &x),
                        "system {s} round {round} version {version}: served bits match \
                         neither the refactor chain nor any recovery chain (shards {shards})"
                    );
                }
            });
        }
    });

    // a deadline already past at submission must expire at dispatch,
    // not solve (expire_deadlines is on for the chaos leg)
    submitted.fetch_add(1, Ordering::Relaxed);
    let probe = service
        .submit_with(
            ids[0],
            oracle.rhs[0].clone(),
            Priority::Deadline(Instant::now() - Duration::from_millis(2)),
        )
        .unwrap();
    match probe.wait() {
        Err(Error::DeadlineExpired) => {
            completed.fetch_add(1, Ordering::Relaxed);
        }
        Err(e) => panic!("expired probe resolved with the wrong error: {e}"),
        Ok(_) => panic!("expired probe solved instead of expiring"),
    }

    // every quarantined system must serve again, and the post-recovery
    // solve must be bit-identical to a clean full-pivot chain (a
    // candidate at the final version)
    for (s, id) in ids.iter().enumerate() {
        let mut tries = 0;
        let x = loop {
            submitted.fetch_add(1, Ordering::Relaxed);
            match service.solve(*id, oracle.rhs[s].clone()) {
                Ok(x) => {
                    completed.fetch_add(1, Ordering::Relaxed);
                    break x;
                }
                Err(_) => {
                    completed.fetch_add(1, Ordering::Relaxed);
                    tries += 1;
                    assert!(tries < 500, "system {s} never recovered");
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        };
        assert!(
            oracle.candidates[s][VERSIONS - 1].iter().any(|e| e == &x),
            "post-recovery solve, system {s} (shards {shards})"
        );
        assert!(
            matches!(service.health(*id), Some(Health::Healthy)),
            "system {s} healthy at exit (shards {shards})"
        );
    }

    // zero lost or double-completed tickets, even through panics
    assert_eq!(
        submitted.load(Ordering::Relaxed),
        completed.load(Ordering::Relaxed),
        "every accepted ticket resolves exactly once (shards {shards})"
    );
    let st = service.stats();
    assert!(plan.injected() >= 1, "the fault plan actually fired");
    assert!(
        st.panics_caught >= 1,
        "shard supervision caught at least one injected panic (shards {shards})"
    );
    assert!(
        st.quarantines >= 1,
        "at least one system was quarantined (shards {shards})"
    );
    assert!(
        st.recoveries >= 1,
        "at least one quarantine recovered via escalation (shards {shards})"
    );
    assert!(st.expired >= 1, "the stale deadline probe expired");
    drop(service);
}

#[test]
fn shedding_rejects_saturated_bulk_admissions() {
    // a slow-kernel plan stalls the dispatcher mid-solve, so queue
    // depth builds deterministically behind it
    let plan = Arc::new(FaultPlan::new(1, 1, vec![Fault::SlowKernel(20_000)]));
    let mut cfg = soak_cfg(1);
    cfg.shed_depth = 2;
    let service = SolverService::with_shards(cfg).unwrap();
    let base = gen::power_network(120, 3);
    let solver = SolverBuilder::new().threads(1).fault(plan).build().unwrap();
    let sys = solver.analyze(&base).unwrap().factor().unwrap();
    let id = service.register(sys).unwrap();
    let b = gen::rhs_for_ones(&base);

    // the first submission is drained immediately; the dispatcher then
    // sleeps ~20ms inside the injected slow kernel while the following
    // submissions pile up behind it
    let mut kept = vec![service.submit(id, b.clone()).unwrap()];
    std::thread::sleep(Duration::from_millis(5));
    let mut shed = 0usize;
    for _ in 0..8 {
        match service.submit(id, b.clone()) {
            Ok(t) => kept.push(t),
            Err(e) => {
                assert!(
                    e.to_string().contains("shedding bulk load"),
                    "unexpected admission error: {e}"
                );
                shed += 1;
            }
        }
    }
    assert!(shed >= 1, "bulk admissions shed at depth >= shed_depth");
    // deadline-lane admissions are never shed — they ride backpressure
    kept.push(
        service
            .submit_with(id, b.clone(), Priority::Deadline(Instant::now()))
            .unwrap(),
    );
    for t in kept {
        t.wait().unwrap();
    }
    assert!(service.stats().shed >= 1, "the shed counter recorded it");
}

//! FFI round-trip smoke tests: drive the `extern "C"` entry points the
//! way a C caller would (raw CSR arrays, opaque handle, numeric status
//! codes) and check the full Analyze → Factorize → Solve → ReFactorize →
//! Solve lifecycle, the out-of-order guards, and message reporting.
//!
//! Built only with `--features ffi` (see `[[test]]` in Cargo.toml).

use std::ffi::CStr;

use hylu::ffi::{
    hylu_analyze, hylu_create, hylu_factorize, hylu_free, hylu_last_error, hylu_n, hylu_nnz,
    hylu_refactorize, hylu_service_create, hylu_service_free, hylu_service_health,
    hylu_service_last_error, hylu_service_rebalance, hylu_service_register, hylu_service_retire,
    hylu_service_solve, hylu_solve, hylu_solve_many, HyluHandle, HyluService, HYLU_ERR_INVALID,
    HYLU_OK,
};
use hylu::prelude::*;
use hylu::sparse::gen;

/// A matrix in the raw arrays a C caller would hold.
struct RawCsr {
    n: i64,
    ap: Vec<i64>,
    ai: Vec<i64>,
    ax: Vec<f64>,
}

fn raw(a: &Csr) -> RawCsr {
    RawCsr {
        n: a.n as i64,
        ap: a.indptr.iter().map(|&p| p as i64).collect(),
        ai: a.indices.iter().map(|&j| j as i64).collect(),
        ax: a.vals.clone(),
    }
}

#[test]
fn ffi_lifecycle_roundtrip_matches_rust_api() {
    let a = gen::grid2d(12, 12);
    let b = gen::rhs_for_ones(&a);
    let m = raw(&a);

    unsafe {
        let mut h: *mut HyluHandle = std::ptr::null_mut();
        assert_eq!(hylu_create(1, 1, &mut h), HYLU_OK);
        assert!(!h.is_null());

        // out-of-order calls are state errors, not UB
        assert_eq!(hylu_factorize(h), HYLU_ERR_INVALID);
        assert_eq!(hylu_refactorize(h, m.ax.as_ptr()), HYLU_ERR_INVALID);
        let msg = CStr::from_ptr(hylu_last_error(h)).to_str().unwrap();
        assert!(msg.contains("before"), "unhelpful message: {msg}");

        assert_eq!(
            hylu_analyze(h, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr()),
            HYLU_OK
        );
        assert_eq!(hylu_n(h), m.n);
        assert_eq!(hylu_nnz(h), m.ax.len() as i64);
        assert_eq!(hylu_factorize(h), HYLU_OK);

        let mut x = vec![0.0f64; a.n];
        assert_eq!(hylu_solve(h, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        // bit-identical to the same lifecycle through the Rust handles
        let solver = SolverBuilder::new().repeated().threads(1).build().unwrap();
        let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
        assert_eq!(x, sys.solve(&b).unwrap());

        // refactorize with scaled values: solution halves
        let ax2: Vec<f64> = m.ax.iter().map(|v| v * 2.0).collect();
        assert_eq!(hylu_refactorize(h, ax2.as_ptr()), HYLU_OK);
        assert_eq!(hylu_solve(h, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        sys.refactor(&ax2).unwrap();
        assert_eq!(x, sys.solve(&b).unwrap());
        assert!(x.iter().all(|v| (v - 0.5).abs() < 1e-8));

        hylu_free(h);
    }
}

#[test]
fn ffi_solve_many_packs_columns() {
    let a = gen::power_network(200, 7);
    let b1 = gen::rhs_for_ones(&a);
    let b2: Vec<f64> = b1.iter().map(|v| v * 3.0).collect();
    let m = raw(&a);
    unsafe {
        let mut h: *mut HyluHandle = std::ptr::null_mut();
        assert_eq!(hylu_create(1, 0, &mut h), HYLU_OK);
        assert_eq!(
            hylu_analyze(h, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr()),
            HYLU_OK
        );
        assert_eq!(hylu_factorize(h), HYLU_OK);
        let n = a.n;
        let mut packed = Vec::with_capacity(2 * n);
        packed.extend_from_slice(&b1);
        packed.extend_from_slice(&b2);
        let mut xs = vec![0.0f64; 2 * n];
        assert_eq!(hylu_solve_many(h, 2, packed.as_ptr(), xs.as_mut_ptr()), HYLU_OK);
        assert!(xs[..n].iter().all(|v| (v - 1.0).abs() < 1e-7));
        assert!(xs[n..].iter().all(|v| (v - 3.0).abs() < 1e-7));
        hylu_free(h);
    }
}

#[test]
fn ffi_rejects_malformed_input_with_codes_and_messages() {
    unsafe {
        let mut h: *mut HyluHandle = std::ptr::null_mut();
        assert_eq!(hylu_create(1, 0, &mut h), HYLU_OK);

        // null pointers
        assert_eq!(
            hylu_analyze(h, 2, std::ptr::null(), std::ptr::null(), std::ptr::null()),
            HYLU_ERR_INVALID
        );
        // non-positive n
        let ap = [0i64, 1, 2];
        let ai = [0i64, 1];
        let ax = [1.0f64, 1.0];
        assert_eq!(
            hylu_analyze(h, 0, ap.as_ptr(), ai.as_ptr(), ax.as_ptr()),
            HYLU_ERR_INVALID
        );
        // out-of-bounds column index
        let bad_ai = [0i64, 9];
        assert_eq!(
            hylu_analyze(h, 2, ap.as_ptr(), bad_ai.as_ptr(), ax.as_ptr()),
            HYLU_ERR_INVALID
        );
        let msg = CStr::from_ptr(hylu_last_error(h)).to_str().unwrap();
        assert!(msg.contains("out of bounds"), "{msg}");

        // a structurally singular matrix surfaces its stable code
        // (2x2 with an empty column): ap=[0,1,2], ai=[0,0]
        let sing_ai = [0i64, 0];
        let code = hylu_analyze(h, 2, ap.as_ptr(), sing_ai.as_ptr(), ax.as_ptr());
        assert_eq!(code, hylu::Error::StructurallySingular { matched: 0, n: 0 }.code());

        // null handle is tolerated everywhere
        assert_eq!(hylu_factorize(std::ptr::null_mut()), HYLU_ERR_INVALID);
        assert_eq!(hylu_n(std::ptr::null()), 0);
        hylu_free(std::ptr::null_mut());
        hylu_free(h);
    }
}

#[test]
fn ffi_service_register_retire_roundtrip() {
    let a = gen::grid2d(13, 13);
    let b = gen::rhs_for_ones(&a);
    let m = raw(&a);
    unsafe {
        let mut s: *mut HyluService = std::ptr::null_mut();
        assert_eq!(hylu_service_create(2, 1, &mut s), HYLU_OK);
        assert!(!s.is_null());

        // two registered systems: the base matrix and a doubled copy
        let mut id0 = u64::MAX;
        assert_eq!(
            hylu_service_register(s, m.n, m.ap.as_ptr(), m.ai.as_ptr(), m.ax.as_ptr(), &mut id0),
            HYLU_OK
        );
        let ax2: Vec<f64> = m.ax.iter().map(|v| v * 2.0).collect();
        let mut id1 = u64::MAX;
        assert_eq!(
            hylu_service_register(s, m.n, m.ap.as_ptr(), m.ai.as_ptr(), ax2.as_ptr(), &mut id1),
            HYLU_OK
        );
        assert_ne!(id0, id1);

        // routed solves: x == 1 on the base system, 0.5 on the doubled one,
        // and bit-identical to the same lifecycle through the Rust handles
        let mut x = vec![0.0f64; a.n];
        assert_eq!(hylu_service_solve(s, id0, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        let reference = SolverBuilder::new()
            .repeated()
            .threads(1)
            .build()
            .unwrap()
            .analyze(&a)
            .unwrap()
            .factor()
            .unwrap();
        assert_eq!(x, reference.solve(&b).unwrap());
        assert_eq!(hylu_service_solve(s, id1, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);
        assert!(x.iter().all(|v| (v - 0.5).abs() < 1e-8));

        // rebalance is safe to call at any time
        let mut moved = -1i64;
        assert_eq!(hylu_service_rebalance(s, &mut moved), HYLU_OK);
        assert!(moved >= 0);

        // both systems report healthy (HYLU_HEALTH_OK == 0)
        assert_eq!(hylu_service_health(s, id0), 0);
        assert_eq!(hylu_service_health(s, id1), 0);
        assert_eq!(hylu_service_health(std::ptr::null(), id0), -1);

        // retire: the id is gone for good, with a readable message
        assert_eq!(hylu_service_retire(s, id0), HYLU_OK);
        assert_eq!(
            hylu_service_solve(s, id0, b.as_ptr(), x.as_mut_ptr()),
            HYLU_ERR_INVALID
        );
        let msg = CStr::from_ptr(hylu_service_last_error(s)).to_str().unwrap();
        assert!(msg.contains("unknown system"), "unhelpful message: {msg}");
        assert_eq!(hylu_service_retire(s, id0), HYLU_ERR_INVALID);
        assert_eq!(hylu_service_health(s, id0), -1);
        // the surviving system still serves
        assert_eq!(hylu_service_solve(s, id1, b.as_ptr(), x.as_mut_ptr()), HYLU_OK);

        // null tolerance mirrors the core handle ABI
        assert_eq!(hylu_service_retire(std::ptr::null_mut(), 0), HYLU_ERR_INVALID);
        assert_eq!(
            hylu_service_solve(std::ptr::null_mut(), 0, b.as_ptr(), x.as_mut_ptr()),
            HYLU_ERR_INVALID
        );
        hylu_service_free(std::ptr::null_mut());
        hylu_service_free(s);
    }
}

//! Dispatch-tier equivalence: the scalar, portable and native microkernel
//! tiers must agree on random panels within an ulp-scaled tolerance, the
//! lane kernels must agree bit-for-bit, and the batched `solve_many` path
//! (which rides the lane-major tiling and the panel TRSM+GEMM route) must
//! keep matching independent single-RHS solves exactly.

use hylu::numeric::kernels::{self, KernelTier};
use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn available_tiers() -> Vec<KernelTier> {
    [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native]
        .into_iter()
        .filter(|t| t.available())
        .collect()
}

#[test]
fn property_gemm_tiers_agree_within_ulp_scaled_tolerance() {
    let mut rng = Prng::new(21);
    for round in 0..30 {
        let m = rng.range(1, 40);
        let k = rng.range(1, 40);
        let n = rng.range(1, 70);
        let lda = k + rng.range(0, 5);
        let ldb = n + rng.range(0, 5);
        let ldc = n + rng.range(0, 5);
        let a: Vec<f64> = (0..m * lda).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * ldb).map(|_| rng.normal()).collect();
        let c0: Vec<f64> = (0..m * ldc).map(|_| rng.normal()).collect();
        // per-element magnitude bound sum_p |a||b| drives the ulp scale
        let mut bound = 0.0f64;
        for i in 0..m {
            for j in 0..n {
                let mut s = c0[i * ldc + j].abs();
                for p in 0..k {
                    s += (a[i * lda + p] * b[p * ldb + j]).abs();
                }
                bound = bound.max(s);
            }
        }
        // each tier's error vs the exact product is bounded by ~k ulps of
        // the magnitude sum; allow both sides plus slack
        let tol = 4.0 * (k as f64 + 4.0) * f64::EPSILON * bound;
        let mut ref_c: Option<Vec<f64>> = None;
        for tier in available_tiers() {
            let mut c = c0.clone();
            kernels::gemm_sub(tier, &mut c, ldc, &a, lda, &b, ldb, m, k, n);
            match &ref_c {
                None => ref_c = Some(c),
                Some(want) => {
                    for (x, y) in c.iter().zip(want) {
                        assert!(
                            (x - y).abs() <= tol,
                            "round {round} tier {tier} ({m},{k},{n}): {x} vs {y} tol {tol}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn property_trsm_tiers_agree_within_tolerance() {
    let mut rng = Prng::new(22);
    for &len in &[4usize, 17, 48, 80] {
        let m = rng.range(2, 12);
        let ldu = len + 3;
        let mut u = vec![0.0; (len + 2) * ldu];
        for r in 0..len {
            for c in r..len {
                // strongly diagonally dominant => O(1) condition, so the
                // cross-tier tolerance below stays ulp-scaled
                u[(2 + r) * ldu + 1 + c] = if r == c {
                    2.0 + rng.uniform()
                } else {
                    rng.normal() / len as f64
                };
            }
        }
        let ldx = len + 1;
        let x0: Vec<f64> = (0..m * ldx).map(|_| rng.normal()).collect();
        let mut ref_x: Option<Vec<f64>> = None;
        for tier in available_tiers() {
            let mut x = x0.clone();
            let mut scratch = Vec::new();
            kernels::trsm_right_upper(tier, &mut x, ldx, 0, m, &u, ldu, 2, 1, len, &mut scratch);
            match &ref_x {
                None => ref_x = Some(x),
                Some(want) => {
                    let scale = want.iter().fold(1.0f64, |s, v| s.max(v.abs()));
                    let tol = (len as f64 + 2.0) * 8.0 * f64::EPSILON * scale;
                    for (g, w) in x.iter().zip(want) {
                        assert!(
                            (g - w).abs() <= tol,
                            "tier {tier} len {len}: {g} vs {w} tol {tol}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn solve_many_columns_match_single_rhs_on_wide_supernodes() {
    // mesh + forced-wide supernodes: the panel TRSM+GEMM substitution
    // route must keep batched columns bit-identical to scalar solves
    let a = gen::grid2d(20, 20);
    let solver = SolverBuilder::new()
        .threads(2)
        .repeated() // relaxed supernodes => wide panels
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let mut rng = Prng::new(23);
    for k in [1usize, 4, 16] {
        let bs: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let xs = sys.solve_many(&bs).unwrap();
        for (q, b) in bs.iter().enumerate() {
            let x = sys.solve(b).unwrap();
            assert_eq!(xs[q], x, "k={k} column {q} diverged from the scalar solve");
        }
    }
}

#[test]
fn factor_solve_roundtrip_is_correct_on_every_forced_mode() {
    // end-to-end guard with the dispatched kernels underneath: all three
    // factor kernel families still invert the matrix
    let a = gen::power_network(250, 9);
    let xt: Vec<f64> = (0..a.n).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
    let mut b = vec![0.0; a.n];
    a.matvec(&xt, &mut b);
    for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
        let solver = SolverBuilder::new().kernel(mode).build().unwrap();
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        let x = sys.solve(&b).unwrap();
        let err = hylu::testutil::max_abs_diff(&x, &xt);
        assert!(err < 1e-7, "{mode}: err {err}");
    }
}

#[test]
fn probe_reports_and_calibration_band() {
    let p = kernels::probe();
    assert!(p.gemm_gflops.is_finite() && p.gemm_gflops > 0.0);
    assert!(p.scalar_gflops.is_finite() && p.scalar_gflops > 0.0);
    let cal = kernels::calibration();
    assert!((0.9..=1.5).contains(&cal));
}

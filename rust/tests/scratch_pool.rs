//! `exec::ScratchPool` boundary regressions: the full 64-slot bitmask,
//! a single slot under contention, and LIFO warm-slot reuse observed
//! through the engine's allocation counters.

use std::sync::atomic::Ordering;

use hylu::exec::{Engine, ScratchPool, MAX_SCRATCH_SLOTS};
use hylu::prelude::*;
use hylu::sparse::gen;

#[test]
fn full_width_pool_uses_every_bit_of_the_mask() {
    // cap == MAX_SCRATCH_SLOTS exercises the `u64::MAX` free-mask edge
    // (a plain `(1 << 64) - 1` would overflow)
    let pool = ScratchPool::new(MAX_SCRATCH_SLOTS);
    assert_eq!(pool.capacity(), 64);
    assert_eq!(pool.in_use(), 0);
    let guards: Vec<_> = (0..MAX_SCRATCH_SLOTS).map(|_| pool.checkout()).collect();
    assert_eq!(pool.in_use(), 64, "all 64 slots check out");
    assert!(pool.try_checkout().is_none(), "the 65th caller finds nothing");
    drop(guards);
    assert_eq!(pool.in_use(), 0, "every slot returned");
    // the mask is fully restored: all 64 check out again
    let again: Vec<_> = (0..MAX_SCRATCH_SLOTS).map(|_| pool.checkout()).collect();
    assert_eq!(pool.in_use(), 64);
    drop(again);
}

#[test]
fn oversized_caps_clamp_to_the_mask_width() {
    assert_eq!(ScratchPool::new(65).capacity(), MAX_SCRATCH_SLOTS);
    assert_eq!(ScratchPool::new(usize::MAX).capacity(), MAX_SCRATCH_SLOTS);
    assert_eq!(ScratchPool::new(0).capacity(), 1, "zero clamps up to one");
}

#[test]
fn one_slot_under_contention_stays_exclusive_and_live() {
    // cap 1: every concurrent caller funnels through the condvar
    // fallback; the slot must never be double-handed and all callers
    // must finish (liveness)
    let pool = ScratchPool::new(1);
    std::thread::scope(|sc| {
        for t in 0..8usize {
            let pool = &pool;
            sc.spawn(move || {
                for i in 0..150 {
                    let mut g = pool.checkout();
                    g.y.clear();
                    g.y.push((t * 10_000 + i) as f64);
                    std::thread::yield_now();
                    assert_eq!(
                        g.y[0],
                        (t * 10_000 + i) as f64,
                        "slot mutated by another thread"
                    );
                }
            });
        }
    });
    assert_eq!(pool.in_use(), 0);
}

#[test]
fn lifo_reuse_keeps_sequential_solves_allocation_free() {
    // warm-slot LIFO through a real solver: after one warm-up solve,
    // sequential solves re-check-out the same slot and perform no
    // scratch growth (observed via the engine's allocation counters)
    let a = gen::grid2d(16, 16);
    let solver = SolverBuilder::new()
        .threads(1)
        .scratch_slots(8)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let mut x = Vec::new();
    sys.solve_into(&b, &mut x).unwrap(); // warm-up grows slot 0 once
    let counters = solver.engine().counters();
    let warm = counters.scratch_allocs.load(Ordering::Relaxed);
    for _ in 0..50 {
        sys.solve_into(&b, &mut x).unwrap();
    }
    assert_eq!(
        counters.scratch_allocs.load(Ordering::Relaxed),
        warm,
        "sequential solves must reuse the same warm slot (LIFO)"
    );

    // concurrency exercises additional slots: growth happens (each new
    // slot warms once) but is bounded by the slots actually used
    std::thread::scope(|sc| {
        for _ in 0..4 {
            let (sys, b) = (&sys, &b);
            sc.spawn(move || {
                for _ in 0..20 {
                    sys.solve(b).unwrap();
                }
            });
        }
    });
    let after_burst = counters.scratch_allocs.load(Ordering::Relaxed);
    assert!(
        after_burst >= warm,
        "burst can only add growth, never rewind"
    );
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);

    // back to sequential: the warm slot is the first one free again
    for _ in 0..50 {
        sys.solve_into(&b, &mut x).unwrap();
    }
    assert_eq!(
        counters.scratch_allocs.load(Ordering::Relaxed),
        after_burst,
        "post-burst sequential solves are allocation-free again"
    );
}

#[test]
fn engine_one_slot_pool_serializes_without_growth_churn() {
    // Engine-level cap 1: concurrent solves serialize on the single
    // scratch slot; once it is warm, no further growth events occur no
    // matter how many threads hammer it
    let a = gen::grid2d(12, 12);
    let solver = SolverBuilder::new()
        .threads(1)
        .scratch_slots(1)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    sys.solve(&b).unwrap(); // warm the single slot
    let counters = solver.engine().counters();
    let warm = counters.scratch_allocs.load(Ordering::Relaxed);
    std::thread::scope(|sc| {
        for _ in 0..6 {
            let (sys, b) = (&sys, &b);
            sc.spawn(move || {
                for _ in 0..25 {
                    sys.solve(b).unwrap();
                }
            });
        }
    });
    assert_eq!(
        counters.scratch_allocs.load(Ordering::Relaxed),
        warm,
        "one warm slot serves all contended callers with zero growth"
    );
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);
    let _ = Engine::new(1, 0, 1); // constructor smoke for the cap-1 engine
}

//! End-to-end integration tests: the full pipeline against a dense LU
//! oracle on every matrix class, all kernel modes, one-time and repeated,
//! sequential and parallel — all through the `LinearSystem` handle API.

use hylu::baseline;
use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::{max_abs_diff, Prng};

/// Solve with HYLU and compare against the dense oracle solution.
fn check_against_oracle(a: &Csr, cfg: SolverConfig, tol: f64) {
    let n = a.n;
    let solver = Solver::from_config(cfg).unwrap();
    let sys = solver.analyze(a).unwrap().factor().unwrap();
    let mut rng = Prng::new(99);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x = sys.solve(&b).unwrap();
    let oracle = a.to_dense().solve(&b).expect("oracle solvable");
    let scale = oracle.iter().map(|v| v.abs()).fold(1.0f64, f64::max);
    assert!(
        max_abs_diff(&x, &oracle) / scale < tol,
        "oracle mismatch: {} (scale {scale})",
        max_abs_diff(&x, &oracle)
    );
}

#[test]
fn oracle_all_classes_default_config() {
    for a in [
        gen::circuit(300, 1),
        gen::power_network(250, 2),
        gen::grid2d(16, 16),
        gen::grid3d(6, 6, 6),
        gen::kkt(150, 50, 3),
        gen::banded(200, 5, 4),
        gen::random_sparse(200, 4, 5),
        gen::convdiff2d(14, 14, 8.0, 6),
    ] {
        check_against_oracle(&a, SolverConfig::default(), 1e-7);
    }
}

#[test]
fn oracle_all_kernels_and_threads() {
    let a = gen::grid2d(14, 14);
    for kernel in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
        for threads in [1usize, 3] {
            check_against_oracle(
                &a,
                SolverConfig {
                    kernel: Some(kernel),
                    threads,
                    parallel_solve_min_n: 0,
                    ..SolverConfig::default()
                },
                1e-8,
            );
        }
    }
}

#[test]
fn oracle_baselines() {
    let a = gen::power_network(200, 7);
    check_against_oracle(&a, baseline::pardiso_like(2), 1e-7);
    check_against_oracle(&a, baseline::klu_like(2), 1e-7);
}

#[test]
fn repeated_solve_long_loop_stays_accurate() {
    let a0 = gen::circuit(800, 5);
    let solver = SolverBuilder::new().repeated().threads(2).build().unwrap();
    let mut sys = solver.analyze(&a0).unwrap().factor().unwrap();
    let mut rng = Prng::new(1);
    let mut a = a0.clone();
    for round in 0..10 {
        for v in &mut a.vals {
            *v *= 1.0 + 0.05 * rng.normal();
        }
        sys.refactor(&a.vals).unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| ((i + round) % 13) as f64 - 6.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let x = sys.solve(&b).unwrap();
        assert!(
            max_abs_diff(&x, &xt) < 1e-6,
            "round {round}: {}",
            max_abs_diff(&x, &xt)
        );
    }
}

#[test]
fn indefinite_saddle_point_needs_static_pivoting() {
    // without MC64 the KKT matrix hits tiny pivots and perturbs heavily;
    // with MC64 (default) it solves cleanly
    let a = gen::kkt(200, 80, 9);
    check_against_oracle(&a, SolverConfig::default(), 1e-6);
    // must still produce a usable answer thanks to perturbation+refinement
    let solver = SolverBuilder::new().static_pivoting(false).build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let (_, st) = sys.solve_with_stats(&b).unwrap();
    assert!(st.residual < 1e-6, "residual {}", st.residual);
}

#[test]
fn structurally_singular_matrix_is_rejected() {
    // a matrix with an empty column cannot be matched
    let mut c = Coo::new(4);
    c.push(0, 0, 1.0);
    c.push(1, 1, 1.0);
    c.push(2, 2, 1.0);
    c.push(3, 0, 1.0); // column 3 empty
    let solver = SolverBuilder::new().build().unwrap();
    let err = solver.analyze(c).unwrap_err();
    assert_eq!(err.code(), 4, "structural singularity has a stable code");
}

#[test]
fn numerically_singular_matrix_perturbs_and_reports() {
    // rank-deficient: two identical rows
    let mut c = Coo::new(3);
    for (i, j, v) in [
        (0usize, 0usize, 1.0),
        (0, 1, 2.0),
        (1, 0, 1.0),
        (1, 1, 2.0),
        (1, 2, 1e-30),
        (2, 2, 1.0),
    ] {
        c.push(i, j, v);
    }
    let solver = SolverBuilder::new().build().unwrap();
    let sys = solver.analyze(c).unwrap().factor().unwrap();
    assert!(
        sys.factor_stats().perturbed > 0,
        "expected pivot perturbation"
    );
}

#[test]
fn ill_conditioned_matrix_degrades_gracefully() {
    // Hamrle3-like: both solvers "fail" accuracy in the paper; we still
    // must not panic and must report a (large) residual honestly
    let a = gen::ill_conditioned(500, 3);
    let solver = SolverBuilder::new().build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let (x, st) = sys.solve_with_stats(&b).unwrap();
    assert!(x.iter().all(|v| v.is_finite()));
    assert!(st.residual.is_finite());
}

#[test]
fn matrix_market_roundtrip_through_solver() {
    let a = gen::grid2d(10, 10);
    let dir = std::env::temp_dir().join("hylu_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("grid.mtx");
    hylu::sparse::io::write_matrix_market(&path, &a).unwrap();
    let b = hylu::sparse::io::read_matrix_market(&path).unwrap();
    check_against_oracle(&b, SolverConfig::default(), 1e-8);
    // ...and the path itself is a MatrixInput: ingest directly
    let solver = SolverBuilder::new().build().unwrap();
    let sys = solver.analyze(path.as_path()).unwrap().factor().unwrap();
    let rhs = gen::rhs_for_ones(&a);
    let x = sys.solve(&rhs).unwrap();
    assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-8));
}

//! Handle-API equivalence and typestate-guard tests.
//!
//! The acceptance bar for the API redesign: the `LinearSystem` handle
//! lifecycle (`analyze → factor → refactor → solve`/`solve_many`) must be
//! **bit-identical** to the legacy `(a, &Analysis, &Factorization)`
//! coordinator path it wraps, every `MatrixInput` ingestion route must
//! produce the same matrix, and the guards that used to be runtime
//! errors must hold at the handle level too.

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs_set(n: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect()
}

/// The deprecated coordinator path, quarantined in one helper.
#[allow(deprecated)]
fn legacy_cycle(
    cfg: SolverConfig,
    a: &Csr,
    new_vals: &[f64],
    b: &[f64],
    bs: &[Vec<f64>],
) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
    let solver = hylu::coordinator::Solver::try_new(cfg).unwrap();
    let an = solver.analyze(a).unwrap();
    let mut f = solver.factor(a, &an).unwrap();
    let x_factor = solver.solve(a, &an, &f, b).unwrap();
    let mut a2 = a.clone();
    a2.vals.copy_from_slice(new_vals);
    solver.refactor(&a2, &an, &mut f).unwrap();
    let x_refactor = solver.solve(&a2, &an, &f, b).unwrap();
    let xs = solver.solve_many(&a2, &an, &f, bs).unwrap();
    (x_factor, x_refactor, xs)
}

fn handle_cycle(
    cfg: SolverConfig,
    a: &Csr,
    new_vals: &[f64],
    b: &[f64],
    bs: &[Vec<f64>],
) -> (Vec<f64>, Vec<f64>, Vec<Vec<f64>>) {
    let solver = Solver::from_config(cfg).unwrap();
    let mut sys = solver.analyze(a).unwrap().factor().unwrap();
    let x_factor = sys.solve(b).unwrap();
    sys.refactor(new_vals).unwrap();
    let x_refactor = sys.solve(b).unwrap();
    let xs = sys.solve_many(bs).unwrap();
    (x_factor, x_refactor, xs)
}

#[test]
fn handle_lifecycle_is_bit_identical_to_legacy_path() {
    let mut rng = Prng::new(41);
    for (a, threads) in [
        (gen::grid2d(16, 16), 1usize),
        (gen::circuit(400, 3), 2),
        (gen::kkt(150, 50, 3), 2), // perturbation → refinement engages
    ] {
        let cfg = SolverConfig {
            threads,
            repeated: true,
            parallel_solve_min_n: 0,
            ..SolverConfig::default()
        };
        let new_vals: Vec<f64> = a
            .vals
            .iter()
            .map(|v| v * rng.range_f64(0.8, 1.2))
            .collect();
        let b = gen::rhs_for_ones(&a);
        let bs = rhs_set(a.n, 4, 17);
        let legacy = legacy_cycle(cfg.clone(), &a, &new_vals, &b, &bs);
        let handle = handle_cycle(cfg, &a, &new_vals, &b, &bs);
        assert_eq!(legacy.0, handle.0, "factor+solve diverged (t={threads})");
        assert_eq!(legacy.1, handle.1, "refactor+solve diverged (t={threads})");
        assert_eq!(legacy.2, handle.2, "solve_many diverged (t={threads})");
    }
}

#[test]
fn factorize_matches_first_factor_bitwise() {
    // `factorize` on a Factored handle re-runs exactly what the
    // Analyzed→Factored transition ran
    let a = gen::power_network(300, 5);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let sys1 = solver.analyze(&a).unwrap().factor().unwrap();
    let mut sys2 = solver.analyze(&a).unwrap().factor().unwrap();
    sys2.factorize().unwrap();
    let (f1, f2) = (&sys1.factorization().fac, &sys2.factorization().fac);
    assert_eq!(f1.panels, f2.panels);
    assert_eq!(f1.lvals, f2.lvals);
    assert_eq!(f1.uvals, f2.uvals);
    assert_eq!(f1.pivot_perm, f2.pivot_perm);
}

#[test]
fn builder_presets_set_the_expected_config() {
    let one = SolverBuilder::new().one_shot().build().unwrap();
    assert!(!one.config().repeated);
    let rep = SolverBuilder::new().repeated().threads(3).build().unwrap();
    assert!(rep.config().repeated);
    assert_eq!(rep.config().threads, 3);
    // the escape hatch reaches every raw knob
    let tweaked = SolverBuilder::new()
        .configure(|cfg| cfg.max_supernode = 64)
        .build()
        .unwrap();
    assert_eq!(tweaked.config().max_supernode, 64);
}

#[test]
fn every_matrix_input_route_reaches_the_same_solution() {
    let a = gen::random_sparse(60, 4, 13);
    let b = gen::rhs_for_ones(&a);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let x_csr = solver.analyze(&a).unwrap().factor().unwrap().solve(&b).unwrap();

    // COO route
    let mut coo = Coo::new(a.n);
    for i in 0..a.n {
        for (k, &j) in a.row_indices(i).iter().enumerate() {
            coo.push(i, j, a.row_vals(i)[k]);
        }
    }
    let x_coo = solver.analyze(coo).unwrap().factor().unwrap().solve(&b).unwrap();
    assert_eq!(x_csr, x_coo);

    // CSC route (CSC arrays of A == CSR arrays of Aᵀ)
    let at = a.transpose();
    let x_csc = solver
        .analyze(CscInput::new(&at.indptr, &at.indices, &at.vals))
        .unwrap()
        .factor()
        .unwrap()
        .solve(&b)
        .unwrap();
    assert_eq!(x_csr, x_csc);

    // MatrixMarket path route (text roundtrip loses no f64 precision at
    // 17 significant digits)
    let dir = std::env::temp_dir().join("hylu_api_handles");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("route.mtx");
    hylu::sparse::io::write_matrix_market(&p, &a).unwrap();
    let x_mm = solver
        .analyze(p.as_path())
        .unwrap()
        .factor()
        .unwrap()
        .solve(&b)
        .unwrap();
    assert_eq!(x_csr, x_mm);
}

#[test]
fn refactor_guards_hold_on_handles() {
    let a = gen::grid2d(8, 8);
    let solver = SolverBuilder::new().build().unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let x0 = sys.solve(&b).unwrap();

    // wrong values length
    let err = sys.refactor(&[1.0, 2.0]).unwrap_err();
    assert_eq!(err.code(), 2);

    // different-pattern matrix through refactor_matrix must fail cleanly...
    let wrong = gen::grid2d(8, 9);
    assert!(sys.refactor_matrix(&wrong).is_err());
    // ...and must leave matrix and factors untouched
    assert_eq!(sys.matrix(), &a);
    assert_eq!(sys.solve(&b).unwrap(), x0);

    // same-pattern new values through refactor_matrix are applied
    let mut scaled = a.clone();
    for v in &mut scaled.vals {
        *v *= 2.0;
    }
    sys.refactor_matrix(scaled).unwrap();
    let x2 = sys.solve(&b).unwrap();
    assert!(x2.iter().all(|v| (v - 0.5).abs() < 1e-8));
}

#[test]
fn solve_opts_override_the_configured_refinement() {
    // an ill-conditioned system where refinement actually iterates
    let a = gen::kkt(150, 50, 3);
    let b = gen::rhs_for_ones(&a);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let (_, st_default) = sys.solve_with_stats(&b).unwrap();

    // disabling refinement per call must report zero iterations
    let opts = SolveOpts::new().refine_max_iter(0);
    let (_, st_off) = sys.solve_with_opts(&b, &opts).unwrap();
    assert_eq!(st_off.refine_iters, 0);

    // no overrides == the configured default, bit for bit
    let (x_plain, _) = sys.solve_with_stats(&b).unwrap();
    let (x_noop, st_noop) = sys.solve_with_opts(&b, &SolveOpts::new()).unwrap();
    assert_eq!(x_plain, x_noop);
    assert_eq!(st_noop.refine_iters, st_default.refine_iters);

    // batched path takes the same overrides
    let bs = vec![b.clone(), b.clone()];
    let mut xs = Vec::new();
    let st_many = sys
        .solve_many_into_with_opts(&bs, &mut xs, &opts)
        .unwrap();
    assert_eq!(st_many.refine_iters, 0);
}

#[test]
fn handles_outlive_the_solver_value() {
    // the handle owns the engine (Arc): dropping the Solver value must
    // not invalidate live systems — the property the FFI layer leans on
    let a = gen::grid2d(10, 10);
    let b = gen::rhs_for_ones(&a);
    let sys = {
        let solver = SolverBuilder::new().threads(2).build().unwrap();
        solver.analyze(&a).unwrap().factor().unwrap()
    };
    let x = sys.solve(&b).unwrap();
    assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-8));
}

#[test]
fn error_codes_are_stable() {
    use hylu::Error;
    assert_eq!(Error::Invalid(String::new()).code(), 2);
    assert_eq!(Error::Io(String::new()).code(), 3);
    assert_eq!(Error::StructurallySingular { matched: 0, n: 1 }.code(), 4);
    assert_eq!(Error::ZeroPivot { row: 0 }.code(), 5);
    assert_eq!(Error::Runtime(String::new()).code(), 6);
}

//! Property tests over the full pipeline (hand-rolled harness; see
//! `hylu::testutil::for_each_seed` — seeds are reported on failure for
//! exact replay), driven through the `LinearSystem` handle API.

use hylu::prelude::*;
use hylu::sparse::coo::Coo;
use hylu::testutil::{for_each_seed, Prng};

/// Random structurally-nonsingular matrix: guaranteed transversal on a
/// random permutation plus random clutter, values across several decades.
fn random_matrix(rng: &mut Prng, n: usize) -> Csr {
    let mut c = Coo::new(n);
    let perm = rng.permutation(n);
    for (j, &i) in perm.iter().enumerate() {
        c.push(i, j, rng.nonzero() * 10f64.powf(rng.range_f64(-2.0, 2.0)));
    }
    let extras = rng.range(n, 4 * n);
    for _ in 0..extras {
        c.push(
            rng.below(n),
            rng.below(n),
            rng.nonzero() * 10f64.powf(rng.range_f64(-2.0, 2.0)),
        );
    }
    c.to_csr()
}

#[test]
fn property_residual_bounded_on_random_matrices() {
    for_each_seed(12, |rng| {
        let n = rng.range(10, 120);
        let a = random_matrix(rng, n);
        let solver = SolverBuilder::new()
            .threads(1 + rng.below(3))
            .configure(|cfg| cfg.parallel_solve_min_n = 0)
            .build()
            .unwrap();
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (x, st) = sys.solve_with_stats(&b).unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(
            st.residual < 1e-8,
            "residual {} (n={n}, perturbed={})",
            st.residual,
            sys.factor_stats().perturbed
        );
    });
}

#[test]
fn property_kernels_agree_on_same_matrix() {
    // all three kernels must produce solutions agreeing to fp tolerance
    for_each_seed(8, |rng| {
        let n = rng.range(10, 80);
        let a = random_matrix(rng, n);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut solutions = Vec::new();
        for kernel in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
            let solver = SolverBuilder::new().kernel(kernel).threads(1).build().unwrap();
            let sys = solver.analyze(&a).unwrap().factor().unwrap();
            solutions.push(sys.solve(&b).unwrap());
        }
        let scale = solutions[0]
            .iter()
            .map(|v| v.abs())
            .fold(1.0f64, f64::max);
        for s in &solutions[1..] {
            let d = hylu::testutil::max_abs_diff(&solutions[0], s);
            assert!(d / scale < 1e-6, "kernel disagreement {d} (n={n})");
        }
    });
}

#[test]
fn property_refactor_equals_factor_on_same_values() {
    for_each_seed(8, |rng| {
        let n = rng.range(10, 80);
        let a = random_matrix(rng, n);
        let solver = SolverBuilder::new().threads(1).build().unwrap();
        let sys1 = solver.analyze(&a).unwrap().factor().unwrap();
        let mut sys2 = solver.analyze(&a).unwrap().factor().unwrap();
        sys2.refactor(&a.vals).unwrap();
        let (f1, f2) = (&sys1.factorization().fac, &sys2.factorization().fac);
        assert_eq!(f1.panels, f2.panels);
        assert_eq!(f1.lvals, f2.lvals);
        assert_eq!(f1.uvals, f2.uvals);
        assert_eq!(f1.diag, f2.diag);
        assert_eq!(f1.pivot_perm, f2.pivot_perm);
    });
}

#[test]
fn property_scaled_system_solves_like_unscaled() {
    // row/col scaling of the input must not change the (unscaled) solution
    for_each_seed(6, |rng| {
        let n = rng.range(10, 60);
        let a = random_matrix(rng, n);
        let xt: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; n];
        a.matvec(&xt, &mut b);
        // scale rows of A and b by the same factors
        let factors: Vec<f64> = (0..n).map(|_| 10f64.powf(rng.range_f64(-2.0, 2.0))).collect();
        let mut a2 = a.clone();
        let mut b2 = b.clone();
        for i in 0..n {
            for k in a2.indptr[i]..a2.indptr[i + 1] {
                a2.vals[k] *= factors[i];
            }
            b2[i] *= factors[i];
        }
        let solver = SolverBuilder::new().threads(1).build().unwrap();
        let sys = solver.analyze(&a2).unwrap().factor().unwrap();
        let (x, st) = sys.solve_with_stats(&b2).unwrap();
        // the residual is the robust invariant; solution agreement is
        // condition-limited (row scaling multiplies the condition number)
        assert!(st.residual < 1e-9, "residual {}", st.residual);
        // x-vs-xt agreement is condition-limited on random decade-spanning
        // matrices (the dense oracle drifts identically), so the solution
        // check is only required when the instance is well-conditioned —
        // proxy: the unscaled solve agrees with xt too.
        let solver0 = SolverBuilder::new().threads(1).build().unwrap();
        let sys0 = solver0.analyze(&a).unwrap().factor().unwrap();
        let x0 = sys0.solve(&b).unwrap();
        let scale = xt.iter().map(|v| v.abs()).fold(1.0f64, f64::max);
        let drift0 = hylu::testutil::max_abs_diff(&x0, &xt) / scale;
        if drift0 < 1e-8 {
            let drift = hylu::testutil::max_abs_diff(&x, &xt) / scale;
            assert!(
                drift < 1e-4,
                "scaled solve drifted {drift} while unscaled was {drift0}"
            );
        }
    });
}

#[test]
fn property_multiple_rhs_consistency() {
    // solving k rhs one at a time: each must satisfy its own residual
    for_each_seed(5, |rng| {
        let n = rng.range(20, 80);
        let a = random_matrix(rng, n);
        let solver = SolverBuilder::new().build().unwrap();
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        for _ in 0..4 {
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = sys.solve(&b).unwrap();
            assert!(a.relative_residual(&x, &b) < 1e-8);
        }
    });
}

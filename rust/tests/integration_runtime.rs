//! Cross-layer integration: the Rust PJRT runtime executing the AOT
//! Pallas/JAX artifacts, compared against the native microkernel and used
//! inside the full solver.
//!
//! These tests require `make artifacts` to have run; they are skipped (not
//! failed) when the artifacts are absent so `cargo test` works on a fresh
//! clone.

use hylu::numeric::kernels;
use hylu::prelude::*;
use hylu::runtime::XlaGemm;
use hylu::sparse::gen;
use hylu::testutil::Prng;
use std::path::Path;

fn artifacts() -> Option<XlaGemm> {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match XlaGemm::load(Path::new("artifacts"), 1) {
        Ok(x) => Some(x),
        // stub backend (default build, no `xla` feature) or broken install:
        // skip, don't fail — mirrors the artifacts-missing case
        Err(e) => {
            eprintln!("skipping: xla backend unavailable ({e})");
            None
        }
    }
}

#[test]
fn xla_gemm_matches_native_microkernel() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(3);
    for (m, k, n) in [(4usize, 4, 8), (16, 16, 32), (17, 9, 23), (64, 64, 128), (128, 128, 256)] {
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let got = xla.gemm_update(&c, &a, &b, m, k, n).expect("xla gemm");
        let mut want = c.clone();
        kernels::gemm_sub(kernels::active_tier(), &mut want, n, &a, k, &b, n, m, k, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10, "{m}x{k}x{n}: {g} vs {w}");
        }
    }
}

#[test]
fn xla_trsm_matches_reference() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(7);
    for (w, n) in [(8usize, 16usize), (32, 40), (64, 128)] {
        // bounded-multiplier unit-lower L
        let mut l = vec![0.0f64; w * w];
        for i in 0..w {
            for j in 0..i {
                l[i * w + j] = rng.normal() / w as f64;
            }
        }
        let b: Vec<f64> = (0..w * n).map(|_| rng.normal()).collect();
        let x = xla.trsm_unit_lower(&l, &b, w, n).expect("xla trsm");
        // check L X = B
        for i in 0..w {
            for c in 0..n {
                let mut s = x[i * n + c];
                for j in 0..i {
                    s += l[i * w + j] * x[j * n + c];
                }
                assert!((s - b[i * n + c]).abs() < 1e-9, "({i},{c})");
            }
        }
    }
}

#[test]
fn solver_with_xla_backend_solves_correctly() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let a = gen::grid2d(24, 24);
    let solver = match Solver::from_config(SolverConfig {
        use_xla: true,
        xla_min_dim: 8,
        kernel: Some(KernelMode::SupSup),
        threads: 2,
        ..SolverConfig::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: xla backend unavailable ({e})");
            return;
        }
    };
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let (x, st) = sys.solve_with_stats(&b).unwrap();
    let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
    assert!(err < 1e-8, "err {err} residual {}", st.residual);
}

#[test]
fn xla_backend_agrees_with_native_backend_factors() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let a = gen::banded(300, 12, 5);
    let native = SolverBuilder::new()
        .kernel(KernelMode::SupSup)
        .threads(1)
        .build()
        .unwrap();
    let xla = match Solver::from_config(SolverConfig {
        use_xla: true,
        xla_min_dim: 4,
        kernel: Some(KernelMode::SupSup),
        threads: 1,
        ..SolverConfig::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: xla backend unavailable ({e})");
            return;
        }
    };
    let sys_n = native.analyze(&a).unwrap().factor().unwrap();
    let sys_x = xla.analyze(&a).unwrap().factor().unwrap();
    // same panel values to fp tolerance (same math, different engines)
    let (f_n, f_x) = (sys_n.factorization(), sys_x.factorization());
    assert_eq!(f_n.fac.panels.len(), f_x.fac.panels.len());
    for (p, q) in f_n.fac.panels.iter().zip(&f_x.fac.panels) {
        assert!((p - q).abs() < 1e-9 * (1.0 + p.abs()), "{p} vs {q}");
    }
}

//! Property tests for the coalescing queue's deterministic core and for
//! the service's scheduling invariants, driven by the crate's
//! hand-rolled seed harness (`hylu::testutil::for_each_seed` — proptest
//! is not in the offline registry; failures report the seed for exact
//! replay).
//!
//! Invariants covered:
//! - drain order: FIFO within each priority lane; the deadline lane is
//!   earliest-deadline-first; bulk is never starved beyond the
//!   documented bound; no item is lost or duplicated;
//! - adaptive tick: the window stays within `[0, tick_max]` under
//!   arbitrary drain/idle traces, collapses on idle, and a static
//!   configuration never moves;
//! - end-to-end: batches never exceed `max_batch`, and every ticket of
//!   an arbitrary arrival trace resolves bit-identically to the oracle;
//! - elasticity: arbitrary interleavings of grow / shrink / migrate /
//!   rebalance with in-flight submissions keep every ticket
//!   bit-identical, lose nothing, keep the shard count an exact fold of
//!   the operations applied, and advance the shard epoch monotonically.

use std::time::{Duration, Instant};

use hylu::prelude::*;
use hylu::service::queue::{AdaptiveTick, Drained, LaneQueue};
use hylu::sparse::gen;
use hylu::testutil::{for_each_seed, Prng};

/// Random trace of pushes with lane tags; returns the drained order and
/// the pushed (seq, lane) pairs for cross-checking.
fn random_drain(
    rng: &mut Prng,
    bound: usize,
) -> (Vec<Drained<usize>>, Vec<(u64, Option<Duration>)>) {
    let t0 = Instant::now();
    let mut q = LaneQueue::new();
    let npush = rng.range(1, 60);
    let mut pushed = Vec::with_capacity(npush);
    for i in 0..npush {
        let seq = i as u64;
        if rng.below(3) == 0 {
            // deadline lane, deadlines in arbitrary order (incl. ties)
            let off = Duration::from_micros(rng.below(8) as u64 * 100);
            q.push(seq, Priority::Deadline(t0 + off), i);
            pushed.push((seq, Some(off)));
        } else {
            q.push(seq, Priority::Bulk, i);
            pushed.push((seq, None));
        }
    }
    (q.drain_ordered(bound), pushed)
}

#[test]
fn property_drain_preserves_lane_fifo_and_loses_nothing() {
    for_each_seed(40, |rng| {
        let bound = rng.range(1, 6);
        let (out, pushed) = random_drain(rng, bound);
        assert_eq!(out.len(), pushed.len(), "no item lost or duplicated");
        // each item appears exactly once
        let mut seen = vec![false; pushed.len()];
        for d in &out {
            assert!(!seen[d.item], "item {} duplicated", d.item);
            seen[d.item] = true;
        }
        // FIFO within the bulk lane: seq strictly increasing
        let bulk_seqs: Vec<u64> = out
            .iter()
            .filter(|d| d.deadline.is_none())
            .map(|d| d.seq)
            .collect();
        assert!(bulk_seqs.windows(2).all(|w| w[0] < w[1]), "bulk lane FIFO");
        // deadline lane: earliest deadline first, ties by admission order
        let dl: Vec<(Instant, u64)> = out
            .iter()
            .filter_map(|d| d.deadline.map(|at| (at, d.seq)))
            .collect();
        assert!(
            dl.windows(2).all(|w| w[0] <= w[1]),
            "deadline lane sorted by (deadline, seq)"
        );
    });
}

#[test]
fn property_bulk_never_starves_beyond_the_bound() {
    for_each_seed(40, |rng| {
        let bound = rng.range(1, 6);
        let (out, _) = random_drain(rng, bound);
        // between consecutive bulk items (and before the first one, if
        // any bulk was queued) at most `bound` deadline items appear
        let mut run = 0usize;
        let bulk_remaining = out.iter().filter(|d| d.deadline.is_none()).count();
        let mut left = bulk_remaining;
        for d in &out {
            if d.deadline.is_some() {
                run += 1;
                assert!(
                    left == 0 || run <= bound,
                    "bulk item delayed by {run} deadline items (bound {bound})"
                );
            } else {
                run = 0;
                left -= 1;
            }
        }
    });
}

#[test]
fn property_adaptive_tick_stays_within_bounds() {
    for_each_seed(60, |rng| {
        let tick = Duration::from_micros(rng.below(400) as u64);
        let max = Duration::from_micros(rng.range(1, 4000) as u64);
        let mut t = AdaptiveTick::new(tick, max);
        assert!(t.is_adaptive());
        let max_batch = rng.range(2, 64);
        for _ in 0..rng.range(10, 300) {
            match rng.below(4) {
                0 => t.on_idle(),
                _ => t.on_drain(rng.below(2 * max_batch), max_batch),
            }
            assert!(
                t.window() <= max,
                "window {:?} exceeded tick_max {:?}",
                t.window(),
                max
            );
        }
        t.on_idle();
        assert_eq!(t.window(), Duration::ZERO, "idle collapses the window");
    });
}

#[test]
fn property_static_tick_is_inert() {
    for_each_seed(20, |rng| {
        let tick = Duration::from_micros(rng.below(500) as u64);
        let mut t = AdaptiveTick::new(tick, Duration::ZERO);
        assert!(!t.is_adaptive());
        for _ in 0..50 {
            match rng.below(3) {
                0 => t.on_idle(),
                _ => t.on_drain(rng.below(128), 64),
            }
            assert_eq!(t.window(), tick, "static window never moves");
        }
    });
}

#[test]
fn property_service_batches_capped_and_bit_identical() {
    // arbitrary arrival traces against a real service: batches never
    // exceed max_batch, every ticket resolves with the oracle's bits
    let a = gen::grid2d(14, 14);
    let reference = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let mut seed_rng = Prng::new(0xBEEF);
    let bs: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..a.n).map(|_| seed_rng.normal()).collect())
        .collect();
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| reference.solve(b).unwrap()).collect();
    for_each_seed(6, |rng| {
        let max_batch = rng.range(1, 9);
        let cfg = ServiceConfig {
            shards: 1,
            solver: SolverConfig {
                threads: 1,
                ..SolverConfig::default()
            },
            max_batch,
            tick: Duration::from_micros(500),
            ..ServiceConfig::default()
        };
        let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
        let nreq = rng.range(1, 40);
        let mut tickets = Vec::with_capacity(nreq);
        for _ in 0..nreq {
            let q = rng.below(bs.len());
            let prio = if rng.below(4) == 0 {
                Priority::Deadline(Instant::now() + Duration::from_micros(rng.below(500) as u64))
            } else {
                Priority::Bulk
            };
            tickets.push((q, service.submit_with(SystemId(0), bs[q].clone(), prio).unwrap()));
        }
        for (q, t) in tickets {
            assert_eq!(t.wait().unwrap(), expect[q], "rhs {q}");
        }
        let st = service.stats();
        assert_eq!(st.requests as usize, nreq);
        assert_eq!(st.rhs_solved as usize, nreq);
        assert!(
            st.max_batch <= max_batch,
            "batch {} exceeded cap {max_batch}",
            st.max_batch
        );
    });
}

#[test]
fn property_elastic_topology_preserves_bits_and_tickets() {
    // arbitrary grow/shrink/migrate/rebalance traces with tickets in
    // flight across every transition: the shard count is an exact fold
    // of the applied operations, the shard epoch only moves forward,
    // and every ticket resolves with the oracle's bits
    let a = gen::grid2d(14, 14);
    let reference = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let mut seed_rng = Prng::new(0xE1A5);
    let bs: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..a.n).map(|_| seed_rng.normal()).collect())
        .collect();
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| reference.solve(b).unwrap()).collect();
    for_each_seed(6, |rng| {
        let nsys = rng.range(1, 4);
        let cfg = ServiceConfig {
            shards: rng.range(1, 4),
            solver: SolverConfig {
                threads: 1,
                ..SolverConfig::default()
            },
            max_batch: 8,
            tick: Duration::from_micros(50),
            tick_max: Duration::from_micros(500),
            ..ServiceConfig::default()
        };
        let service = SolverService::new(cfg, vec![a.clone(); nsys]).unwrap();
        let ids = service.system_ids();
        let mut shards = service.shard_count();
        let mut epoch = service.shard_epoch();
        let mut in_flight: Vec<(usize, hylu::service::Ticket)> = Vec::new();
        let mut total = 0usize;
        for _ in 0..rng.range(10, 40) {
            match rng.below(5) {
                0 => {
                    service.grow(1).unwrap();
                    shards += 1;
                }
                1 => {
                    if shards > 1 {
                        service.shrink(1).unwrap();
                        shards -= 1;
                    } else {
                        // the last shard must be defended
                        assert!(service.shrink(1).is_err(), "shrank the last shard");
                    }
                }
                2 => {
                    let id = ids[rng.below(nsys)];
                    service.migrate(id, rng.below(shards)).unwrap();
                }
                3 => {
                    service.rebalance().unwrap();
                }
                _ => {
                    // a burst of tickets left in flight across whatever
                    // topology ops come next
                    for _ in 0..rng.range(1, 5) {
                        let q = rng.below(bs.len());
                        let id = ids[rng.below(nsys)];
                        in_flight.push((q, service.submit(id, bs[q].clone()).unwrap()));
                        total += 1;
                    }
                }
            }
            assert_eq!(service.shard_count(), shards, "count folds the ops");
            let e = service.shard_epoch();
            assert!(e >= epoch, "shard epoch moved backwards");
            epoch = e;
        }
        let n_flight = in_flight.len();
        for (q, t) in in_flight {
            assert_eq!(t.wait().unwrap(), expect[q], "rhs {q}");
        }
        assert_eq!(n_flight, total, "no ticket lost before wait");
        let st = service.stats();
        assert_eq!(st.requests as usize, total, "drained shards' stats folded");
        assert_eq!(st.rhs_solved as usize, total);
    });
}

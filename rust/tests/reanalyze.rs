//! Incremental re-analysis integration tests: the warm / delta-patched /
//! full tiers of `LinearSystem::reanalyze{,_matrix}` must produce
//! analyses (and factors, and solves) bit-identical to the full
//! re-analysis path on the same cached ordering seeds; a warm re-analysis
//! cycle must spawn zero OS threads and grow no engine arena; the
//! per-analysis uid must keep the engine's permuted-matrix MRU from ever
//! serving a stale pattern; the tuner memo must stay keyed by the *new*
//! pattern hash across a re-analysis; the pivot-stability escalation
//! controller must ride the adaptive refactor path without disturbing
//! results; and the service-level live `reanalyze` must match a
//! sequential `LinearSystem` oracle bit-for-bit across the barrier.

use std::time::Duration;

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::{for_each_seed, max_abs_diff, Prng};

/// `a` plus one structural entry at `(i, j)` (which must be absent).
fn with_entry(a: &Csr, i: usize, j: usize, v: f64) -> Csr {
    debug_assert!(!a.indices[a.indptr[i]..a.indptr[i + 1]].contains(&j));
    let mut c = Coo::new(a.n);
    for r in 0..a.n {
        for k in a.indptr[r]..a.indptr[r + 1] {
            c.push(r, a.indices[k], a.vals[k]);
        }
    }
    c.push(i, j, v);
    c.to_csr()
}

/// A column absent from row `i` (never the diagonal).
fn absent_col(a: &Csr, i: usize, rng: &mut Prng) -> usize {
    loop {
        let j = rng.below(a.n);
        if j != i && !a.indices[a.indptr[i]..a.indptr[i + 1]].contains(&j) {
            return j;
        }
    }
}

/// Random local edit: `edits` extra entries scattered over distinct rows.
fn random_edits(a: &Csr, edits: usize, rng: &mut Prng) -> Csr {
    let mut cur = a.clone();
    for _ in 0..edits {
        let i = rng.below(cur.n);
        if cur.indptr[i + 1] - cur.indptr[i] >= cur.n - 1 {
            continue; // row structurally full (modulo the diagonal)
        }
        let j = absent_col(&cur, i, rng);
        cur = with_entry(&cur, i, j, 1e-3);
    }
    cur
}

fn solve_exact(a: &Csr, sys: &LinearSystem<Factored>) -> (Vec<f64>, Vec<f64>) {
    let xt: Vec<f64> = (0..a.n).map(|i| (i % 5) as f64 - 2.0).collect();
    let mut b = vec![0.0; a.n];
    a.matvec(&xt, &mut b);
    (sys.solve(&b).unwrap(), xt)
}

#[test]
fn warm_reanalyze_reuses_the_symbolic_factorization() {
    let a = gen::grid2d(14, 14);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let sym_before = sys.analysis().sym.clone();
    let b = gen::rhs_for_ones(&a);
    let x_before = sys.solve(&b).unwrap();

    // same pattern, perturbed values: the warm tier must reuse the
    // symbolic factorization outright (structural equality) and solve
    // the new values correctly
    let mut a2 = a.clone();
    for v in &mut a2.vals {
        *v *= 1.25;
    }
    let sys = sys.reanalyze(&a2).unwrap();
    assert_eq!(sys.reanalysis_kind(), Some(ReanalyzeKind::Warm));
    assert_eq!(sys.symbolic_stats().replayed_rows, 0);
    assert_eq!(sys.analysis().sym, sym_before, "warm tier must clone the symbolic");
    let sys = sys.factor().unwrap();
    let x_after = sys.solve(&b).unwrap();
    // A scaled by 1.25 ⇒ x scaled by 1/1.25
    for (x2, x1) in x_after.iter().zip(&x_before) {
        assert!((x2 * 1.25 - x1).abs() < 1e-8, "{x2} vs {x1}");
    }
}

#[test]
fn delta_patch_is_bit_identical_to_full_reanalysis() {
    // the delta patcher and the full symbolic fallback run from the same
    // cached ordering seeds, so on the same inputs their analyses — and
    // everything downstream — must be *bit*-identical. Two identically
    // configured solvers differing only in the delta budget provide the
    // oracle: frac 0 forces the full path on the very same edit.
    for a in [gen::grid2d(12, 12), gen::circuit(320, 2), gen::banded(220, 6, 3)] {
        for_each_seed(4, |rng| {
            let edited = random_edits(&a, 1 + rng.below(3), rng);
            let build = |frac: f64| {
                SolverBuilder::new()
                    .threads(1)
                    .reanalyze_delta_frac(frac)
                    .build()
                    .unwrap()
            };
            let mut via_delta = build(0.25).analyze(&a).unwrap().factor().unwrap();
            let mut via_full = build(0.0).analyze(&a).unwrap().factor().unwrap();
            via_delta.reanalyze_matrix(&edited).unwrap();
            via_full.reanalyze_matrix(&edited).unwrap();
            assert_eq!(via_delta.reanalysis_kind(), Some(ReanalyzeKind::Delta));
            assert_eq!(via_full.reanalysis_kind(), Some(ReanalyzeKind::Full));
            assert!(via_delta.symbolic_stats().replayed_rows > 0);
            assert_eq!(
                via_delta.analysis().sym,
                via_full.analysis().sym,
                "patched symbolic diverged from the full re-analysis (n={})",
                a.n
            );
            let (xd, xt) = solve_exact(&edited, &via_delta);
            let (xf, _) = solve_exact(&edited, &via_full);
            assert_eq!(xd, xf, "delta-patched solve must be bit-identical");
            assert!(max_abs_diff(&xd, &xt) < 1e-7, "err {}", max_abs_diff(&xd, &xt));
        });
    }
}

#[test]
fn edits_wider_than_the_budget_fall_back_to_full() {
    let a = gen::grid2d(10, 10);
    let mut rng = Prng::new(9);
    // touch every even row: half the rows change structure, well over
    // the default 25% delta budget
    let mut edited = a.clone();
    for i in (0..a.n).step_by(2) {
        let j = absent_col(&edited, i, &mut rng);
        edited = with_entry(&edited, i, j, 1e-3);
    }
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    sys.reanalyze_matrix(&edited).unwrap();
    assert_eq!(sys.reanalysis_kind(), Some(ReanalyzeKind::Full));
    let (x, xt) = solve_exact(&edited, &sys);
    assert!(max_abs_diff(&x, &xt) < 1e-7);
}

#[test]
fn massive_pattern_changes_restart_with_fresh_ordering() {
    // beyond `reanalyze_cold_frac` of changed rows the cached matching/
    // scaling/ordering seeds are dropped: the re-analysis must be a true
    // cold restart, bit-identical to `Solver::analyze` of the new matrix
    // (same fresh ordering), not a symbolic re-run under stale seeds
    let a = gen::grid2d(10, 10);
    let mut rng = Prng::new(41);
    let mut edited = a.clone();
    for i in 0..a.n {
        if edited.indptr[i + 1] - edited.indptr[i] >= edited.n - 1 {
            continue;
        }
        let j = absent_col(&edited, i, &mut rng);
        edited = with_entry(&edited, i, j, 1e-3);
    }
    let build = || SolverBuilder::new().threads(1).build().unwrap();
    let mut sys = build().analyze(&a).unwrap().factor().unwrap();
    sys.reanalyze_matrix(&edited).unwrap();
    assert_eq!(sys.reanalysis_kind(), Some(ReanalyzeKind::Full));
    let cold = build().analyze(&edited).unwrap().factor().unwrap();
    assert_eq!(
        sys.analysis().sym,
        cold.analysis().sym,
        "cold restart must match Solver::analyze bit for bit"
    );
    let (x, xt) = solve_exact(&edited, &sys);
    let (xc, _) = solve_exact(&edited, &cold);
    assert_eq!(x, xc);
    assert!(max_abs_diff(&x, &xt) < 1e-7);
}

#[test]
fn dimension_change_takes_the_cold_path() {
    let a = gen::grid2d(8, 8);
    let bigger = gen::grid2d(9, 9);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    sys.reanalyze_matrix(&bigger).unwrap();
    assert_eq!(sys.reanalysis_kind(), Some(ReanalyzeKind::Full));
    assert_eq!(sys.n(), bigger.n);
    let (x, xt) = solve_exact(&bigger, &sys);
    assert!(max_abs_diff(&x, &xt) < 1e-7);
}

#[test]
fn failed_reanalyze_matrix_leaves_the_system_usable() {
    let a = gen::grid2d(8, 8);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let x0 = sys.solve(&b).unwrap();
    // structurally broken input: indptr not monotone
    let bad = Csr {
        n: 2,
        indptr: vec![0, 2, 1],
        indices: vec![0, 1, 1],
        vals: vec![1.0, 2.0, 3.0],
    };
    assert!(sys.reanalyze_matrix(bad).is_err());
    // commit-on-success: the old matrix, analysis, and factors survive
    assert_eq!(sys.reanalysis_kind(), None);
    assert_eq!(sys.solve(&b).unwrap(), x0);
}

#[test]
fn warm_reanalyze_cycle_spawns_nothing_and_keeps_arenas_warm() {
    let a = gen::grid2d(20, 20);
    let solver = SolverBuilder::new()
        .repeated()
        .threads(3)
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let mut x = Vec::new();

    // warm-up: one full reanalyze + factor + solve cycle grows every
    // arena to its high-water mark
    sys.reanalyze_matrix(&a).unwrap();
    sys.solve_into(&b, &mut x).unwrap();
    let spawned = solver.engine().threads_spawned();
    let allocs = solver.engine().scratch_alloc_events();
    assert_eq!(spawned, 2, "pool of 3 spawns exactly 2 OS threads");

    let cycles = 3u64;
    for _ in 0..cycles {
        sys.reanalyze_matrix(&a).unwrap();
        assert_eq!(sys.reanalysis_kind(), Some(ReanalyzeKind::Warm));
        let st = sys.solve_into(&b, &mut x).unwrap();
        assert!(st.residual < 1e-10, "residual {}", st.residual);
    }
    assert_eq!(
        solver.engine().threads_spawned(),
        spawned,
        "warm re-analysis must spawn no OS threads"
    );
    // every cycle pays exactly one accounted event: the permuted-matrix
    // MRU insert under the analysis' fresh uid (the stale-cache defense
    // working as designed). The worker arenas themselves must not grow.
    assert_eq!(
        solver.engine().scratch_alloc_events(),
        allocs + cycles,
        "warm re-analysis cycles must not grow any scratch arena"
    );
}

#[test]
fn reanalyzed_system_never_reuses_a_stale_permuted_cache_entry() {
    // two handles on one engine, one of them re-analyzed to a different
    // pattern: the per-analysis uid keys the engine's permuted-matrix
    // MRU, so interleaved refactor/solve traffic must never observe the
    // other (or the pre-reanalysis) pattern's cached values
    let a = gen::grid2d(12, 12);
    let mut rng = Prng::new(17);
    let edited = random_edits(&a, 2, &mut rng);
    let solver = SolverBuilder::new()
        .threads(2)
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .unwrap();
    let mut moving = solver.analyze(&a).unwrap().factor().unwrap();
    let mut pinned = solver.analyze(&a).unwrap().factor().unwrap();
    moving.reanalyze_matrix(&edited).unwrap();
    assert_eq!(moving.reanalysis_kind(), Some(ReanalyzeKind::Delta));
    for _ in 0..4 {
        moving.refactor(&edited.vals).unwrap();
        let (xm, xmt) = solve_exact(&edited, &moving);
        assert!(
            max_abs_diff(&xm, &xmt) < 1e-7,
            "stale permuted cache on the re-analyzed handle: err {}",
            max_abs_diff(&xm, &xmt)
        );
        pinned.refactor(&a.vals).unwrap();
        let (xp, xpt) = solve_exact(&a, &pinned);
        assert!(
            max_abs_diff(&xp, &xpt) < 1e-7,
            "stale permuted cache on the pinned handle: err {}",
            max_abs_diff(&xp, &xpt)
        );
    }
}

#[test]
fn tuner_memo_is_keyed_by_the_new_pattern_hash_across_reanalysis() {
    // re-analysis to a changed pattern re-tunes under the NEW pattern
    // hash. The memo then serves that exact plan to any later analysis of
    // the same pattern — and the original pattern's entry must survive
    // untouched (a collision between the two hashes would cross the plans)
    let a = gen::grid2d(10, 10);
    let mut rng = Prng::new(23);
    let edited = random_edits(&a, 1, &mut rng);
    let build = || {
        SolverBuilder::new()
            .threads(1)
            .tuning(Tuning::Quick)
            .build()
            .unwrap()
    };
    let solver = build();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let plan_a = sys.analysis().plan.kernel;
    sys.reanalyze_matrix(&edited).unwrap();
    let plan_edited = sys.analysis().plan.kernel;
    // memo hit: a later analysis of the edited pattern gets the plan the
    // re-analysis tuned and memoized (timing noise cannot diverge them)
    let cold_edited = build().analyze(&edited).unwrap();
    assert_eq!(cold_edited.analysis().plan.kernel, plan_edited);
    // ...and the original pattern's memo entry was not clobbered
    let cold_a = build().analyze(&a).unwrap();
    assert_eq!(cold_a.analysis().plan.kernel, plan_a);
}

#[test]
fn adaptive_handles_expose_the_controller_and_default_ones_do_not() {
    let a = gen::grid2d(8, 8);
    let plain = SolverBuilder::new().threads(1).build().unwrap();
    let sys = plain.analyze(&a).unwrap().factor().unwrap();
    assert!(sys.escalation().is_none(), "adaptive path is opt-in");

    let adaptive = SolverBuilder::new()
        .threads(1)
        .adaptive_refactor(true)
        .build()
        .unwrap();
    let sys = adaptive.analyze(&a).unwrap().factor().unwrap();
    assert!(sys.escalation().is_some());
}

#[test]
fn stable_refactor_traces_stay_on_the_replay_tier() {
    let a = gen::grid2d(12, 12);
    let solver = SolverBuilder::new()
        .threads(1)
        .adaptive_refactor(true)
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let steps = 6u64;
    for k in 0..steps {
        // gentle value drift: pivot growth stays in its stable band
        let vals: Vec<f64> = a.vals.iter().map(|v| v * (1.0 + 0.01 * k as f64)).collect();
        sys.refactor(&vals).unwrap();
    }
    let esc = sys.escalation().unwrap();
    assert_eq!(
        esc.counts(),
        (steps, 0, 0),
        "a stable trace must never leave the replay tier"
    );
    assert!(esc.fast_ema().is_finite() && esc.slow_ema().is_finite());
}

#[test]
fn forced_reorder_tier_keeps_solves_accurate() {
    // reorder_growth clamped to 1.0 promotes every refactor to the
    // secondary within-block reordering tier — results must stay correct
    // (the reorder is pattern-preserving; the KKT saddle point's
    // perturbed pivots keep growth strictly above 1, so the clamped
    // threshold always fires)
    let a = gen::kkt(120, 40, 7);
    let solver = SolverBuilder::new()
        .threads(1)
        .adaptive_refactor(true)
        .escalation_thresholds(0.0, 1e30)
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    for _ in 0..3 {
        sys.refactor(&a.vals).unwrap();
        let (x, xt) = solve_exact(&a, &sys);
        assert!(max_abs_diff(&x, &xt) < 1e-6, "err {}", max_abs_diff(&x, &xt));
    }
    let (_, reorders, _) = sys.escalation().unwrap().counts();
    assert!(reorders > 0, "clamped threshold must engage the reorder tier");
}

#[test]
fn tiny_repivot_threshold_forces_full_repivots() {
    // both thresholds clamp to 1.0: every refactor escalates straight to
    // a full re-pivoting factorization (KKT pivot growth sits strictly
    // above 1), the controller resets after each, and results stay correct
    let a = gen::kkt(120, 40, 3);
    let solver = SolverBuilder::new()
        .threads(1)
        .adaptive_refactor(true)
        .escalation_thresholds(0.0, 0.0)
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    for _ in 0..3 {
        sys.refactor(&a.vals).unwrap();
        let (x, xt) = solve_exact(&a, &sys);
        assert!(max_abs_diff(&x, &xt) < 1e-6);
    }
    let (replays, _, repivots) = sys.escalation().unwrap().counts();
    assert_eq!(replays, 0);
    assert_eq!(repivots, 3);
}

/// Shard count from `HYLU_TEST_SHARDS` when set (the CI dynamic job's
/// 1-vs-4 matrix), both regimes otherwise.
fn shard_counts() -> Vec<usize> {
    match std::env::var("HYLU_TEST_SHARDS") {
        Ok(v) => vec![v.parse().expect("HYLU_TEST_SHARDS must be a number")],
        Err(_) => vec![1, 4],
    }
}

#[test]
fn service_live_reanalyze_matches_a_sequential_oracle() {
    for shards in shard_counts() {
        service_reanalyze_once(shards);
    }
}

fn service_reanalyze_once(shards: usize) {
    let a = gen::grid2d(16, 16);
    let cfg = ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        tick: Duration::ZERO,
        ..ServiceConfig::default()
    };
    let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
    // identically configured sequential oracle: the deterministic
    // pipeline makes results bit-comparable
    let mut oracle = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let b = gen::rhs_for_ones(&a);
    assert_eq!(service.solve(SystemId(0), b.clone()).unwrap(), oracle.solve(&b).unwrap());

    let mut rng = Prng::new(31 + shards as u64);
    let edited = random_edits(&a, 2, &mut rng);
    // barrier contract: tickets admitted before the re-analysis flush
    // against the old factors, later ones observe the new matrix
    let before: Vec<_> = (0..4)
        .map(|_| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();
    service.reanalyze(SystemId(0), edited.clone()).unwrap();
    let after: Vec<_> = (0..4)
        .map(|_| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();

    let x_old = oracle.solve(&b).unwrap();
    oracle.reanalyze_matrix(&edited).unwrap();
    let x_new = oracle.solve(&b).unwrap();
    for t in before {
        assert_eq!(t.wait().unwrap(), x_old, "pre-barrier ticket saw the new matrix");
    }
    for t in after {
        assert_eq!(t.wait().unwrap(), x_new, "post-barrier ticket saw the old matrix");
    }
    assert_eq!(service.stats().reanalyzes, 1);

    // routing carries n per system: a size change is rejected up front
    assert!(service.reanalyze(SystemId(0), gen::grid2d(3, 3)).is_err());
}

//! Autotuner contract tests.
//!
//! - Every enumerated GEMM tile variant (and the avx512 tier, whose
//!   dispatch arm is safe Rust and therefore callable everywhere) is
//!   **bit-identical** to the scalar reference, including every remainder
//!   edge around the tile boundaries — swapping kernel plans must never
//!   change factor bits.
//! - A-operand packing is bit-neutral: same values, same FP order, only
//!   the leading dimension changes.
//! - The on-disk tune cache round-trips plans and tolerates truncated,
//!   garbage, and version-bumped files (returns `None`, never errors).
//! - `Tuning::Quick` end to end: tuned solves are correct, and warm
//!   refactor replay under a tuned plan is bitwise deterministic.

use hylu::numeric::kernels::{self, tuner, GemmVariant, KernelPlan, KernelTier, Tuning};
use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::symbolic::{analyze_pattern, MergePolicy};

/// Deterministic non-trivial fill (matches the tuner's probe idiom).
fn fill(buf: &mut [f64], phase: usize) {
    for (i, v) in buf.iter_mut().enumerate() {
        *v = (((i * 7 + phase * 13) % 23) as f64 - 11.0) * 0.0625;
    }
}

/// Edge sizes around a tile boundary `t`: 1, small odds, t-1, t, t+1.
fn edges(t: usize) -> Vec<usize> {
    let mut v = vec![1, 3, 7, t.saturating_sub(1), t, t + 1, 2 * t + 3];
    v.retain(|&x| x > 0);
    v.sort_unstable();
    v.dedup();
    v
}

/// Scalar-reference GEMM into a fresh copy of `c0`.
#[allow(clippy::too_many_arguments)]
fn scalar_ref(
    c0: &[f64],
    ldc: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f64> {
    let mut c = c0.to_vec();
    kernels::gemm_sub(KernelTier::Scalar, &mut c, ldc, a, lda, b, ldb, m, k, n);
    c
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} differs ({g:e} vs {w:e})"
        );
    }
}

#[test]
fn every_tile_variant_is_bit_identical_to_scalar_on_remainder_edges() {
    for &(mr, nr, ku) in tuner::TILE_VARIANTS.iter() {
        for m in edges(mr as usize) {
            for n in edges(nr as usize) {
                for k in edges(ku as usize).into_iter().chain([13]) {
                    let (lda, ldb, ldc) = (k + 3, n + 2, n + 5);
                    let mut a = vec![0.0; m * lda];
                    let mut b = vec![0.0; k * ldb];
                    let mut c0 = vec![0.0; m * ldc];
                    fill(&mut a, 1);
                    fill(&mut b, 2);
                    fill(&mut c0, 3);
                    let want = scalar_ref(&c0, ldc, &a, lda, &b, ldb, m, k, n);
                    let mut c = c0.clone();
                    unsafe {
                        tuner::gemm_sub_tiled(
                            mr,
                            nr,
                            ku,
                            c.as_mut_ptr(),
                            ldc,
                            a.as_ptr(),
                            lda,
                            b.as_ptr(),
                            ldb,
                            m,
                            k,
                            n,
                        );
                    }
                    assert_bits_eq(&c, &want, &format!("tile {mr}x{nr}/u{ku} m={m} k={k} n={n}"));
                }
            }
        }
    }
}

#[test]
fn avx512_gemm_is_bit_identical_to_scalar() {
    // the avx512 dispatch arm is blocked safe Rust (no intrinsics), so it
    // runs — and must match scalar bits — whether or not the CPU/compile
    // flags make it the *preferred* tier
    for m in [1usize, 3, 7, 8, 9, 15, 16, 17, 33] {
        for n in [1usize, 3, 7, 15, 16, 17, 31, 33] {
            for k in [1usize, 5, 8, 24] {
                let (lda, ldb, ldc) = (k + 1, n + 4, n + 1);
                let mut a = vec![0.0; m * lda];
                let mut b = vec![0.0; k * ldb];
                let mut c0 = vec![0.0; m * ldc];
                fill(&mut a, 4);
                fill(&mut b, 5);
                fill(&mut c0, 6);
                let want = scalar_ref(&c0, ldc, &a, lda, &b, ldb, m, k, n);
                let mut c = c0.clone();
                kernels::gemm_sub(KernelTier::Avx512, &mut c, ldc, &a, lda, &b, ldb, m, k, n);
                assert_bits_eq(&c, &want, &format!("avx512 m={m} k={k} n={n}"));
            }
        }
    }
}

#[test]
fn packed_a_is_bit_neutral_for_every_plan() {
    let (m, k, n) = (17usize, 13usize, 29usize);
    let lda = k + 6;
    let mut a = vec![0.0; m * lda];
    let mut b = vec![0.0; k * n];
    let mut c0 = vec![0.0; m * n];
    fill(&mut a, 7);
    fill(&mut b, 8);
    fill(&mut c0, 9);
    let mut packed = Vec::new();
    kernels::pack_rows(&mut packed, &a, lda, m, k);
    assert_eq!(packed.len(), m * k);
    let mut variants = vec![GemmVariant::Tier];
    variants.extend(
        tuner::TILE_VARIANTS
            .iter()
            .map(|&(mr, nr, ku)| GemmVariant::Tiled { mr, nr, ku }),
    );
    for tier in [KernelTier::Scalar, KernelTier::Portable, KernelTier::Avx512] {
        for gemm in &variants {
            let plan = KernelPlan { gemm: *gemm, ..Default::default() };
            let mut c_strided = c0.clone();
            kernels::gemm_sub_planned(tier, &plan, &mut c_strided, n, &a, lda, &b, n, m, k, n);
            let mut c_packed = c0.clone();
            kernels::gemm_sub_planned(tier, &plan, &mut c_packed, n, &packed, k, &b, n, m, k, n);
            assert_bits_eq(
                &c_packed,
                &c_strided,
                &format!("pack-A neutrality tier={tier} gemm={gemm}"),
            );
        }
    }
}

#[test]
fn planned_gemm_tile_variants_match_scalar_through_the_dispatcher() {
    let (m, k, n) = (19usize, 11usize, 27usize);
    let mut a = vec![0.0; m * k];
    let mut b = vec![0.0; k * n];
    let mut c0 = vec![0.0; m * n];
    fill(&mut a, 10);
    fill(&mut b, 11);
    fill(&mut c0, 12);
    let want = scalar_ref(&c0, n, &a, k, &b, n, m, k, n);
    for &(mr, nr, ku) in tuner::TILE_VARIANTS.iter() {
        let plan = KernelPlan {
            gemm: GemmVariant::Tiled { mr, nr, ku },
            ..Default::default()
        };
        // any tier: the tiled variant overrides the tier microkernel
        for tier in [KernelTier::Scalar, KernelTier::Portable, KernelTier::Avx512] {
            let mut c = c0.clone();
            kernels::gemm_sub_planned(tier, &plan, &mut c, n, &a, k, &b, n, m, k, n);
            assert_bits_eq(&c, &want, &format!("planned {mr}x{nr}/u{ku} on {tier}"));
        }
    }
}

#[test]
fn trsm_threshold_paths_agree_numerically() {
    // the two TRSM paths the tuned thresholds choose between may differ
    // by rounding but must agree to solver-grade accuracy
    let (m, len) = (24usize, 40usize);
    let ldu = len + 1;
    let mut u = vec![0.0; len * ldu];
    for r in 0..len {
        for c in r..len {
            u[r * ldu + c] = if r == c {
                3.0 + (c % 7) as f64 * 0.25
            } else {
                0.01 * ((r + c) % 5) as f64
            };
        }
    }
    let mut x0 = vec![0.0; m * len];
    fill(&mut x0, 13);
    let mut run = |min_len: usize, min_m: usize| {
        let mut x = x0.clone();
        let mut scratch = Vec::new();
        kernels::trsm_right_upper_with(
            KernelTier::Portable,
            &mut x,
            len,
            0,
            m,
            &u,
            ldu,
            0,
            0,
            len,
            &mut scratch,
            min_len,
            min_m,
        );
        x
    };
    let gather = run(0, 0);
    let direct = run(usize::MAX, usize::MAX);
    for (g, d) in gather.iter().zip(&direct) {
        assert!(
            (g - d).abs() <= 1e-12 * d.abs().max(1.0),
            "TRSM gather/direct diverged: {g:e} vs {d:e}"
        );
    }
}

/// Unique-per-test temp dir (this binary's tests run concurrently).
fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hylu-tune-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn disk_cache_roundtrips_every_plan_shape() {
    let dir = temp_cache_dir("roundtrip");
    let tier = KernelTier::Portable;
    let mut plans = vec![KernelPlan::default()];
    for &(mr, nr, ku) in tuner::TILE_VARIANTS.iter() {
        plans.push(KernelPlan {
            gemm: GemmVariant::Tiled { mr, nr, ku },
            pack_a: (mr + nr) % 2 == 0,
            trsm_min_len: 32,
            trsm_min_m: 4,
        });
    }
    for (i, plan) in plans.iter().enumerate() {
        let hash = 0xABCD_0000 + i as u64;
        assert_eq!(tuner::load_cached(&dir, tier, hash), None, "cold cache");
        tuner::store_cached(&dir, tier, hash, plan);
        assert_eq!(tuner::load_cached(&dir, tier, hash), Some(*plan));
        // keyed by tier too: another tier misses
        assert_eq!(tuner::load_cached(&dir, KernelTier::Scalar, hash), None);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disk_cache_tolerates_truncated_garbage_and_version_bumped_files() {
    let dir = temp_cache_dir("corrupt");
    let tier = KernelTier::Portable;
    let plan = KernelPlan {
        gemm: GemmVariant::Tiled { mr: 8, nr: 16, ku: 4 },
        pack_a: true,
        trsm_min_len: 64,
        trsm_min_m: 16,
    };
    tuner::store_cached(&dir, tier, 1, &plan);
    let path = std::fs::read_dir(&dir)
        .unwrap()
        .next()
        .unwrap()
        .unwrap()
        .path();
    let good = std::fs::read_to_string(&path).unwrap();
    assert!(good.starts_with(&format!("hylu-tune-cache v{}", tuner::TUNE_CACHE_VERSION)));

    // truncated: drop the trsm line
    let truncated: String = good.lines().take(3).map(|l| format!("{l}\n")).collect();
    std::fs::write(&path, truncated).unwrap();
    assert_eq!(tuner::load_cached(&dir, tier, 1), None, "truncated file must be ignored");

    // garbage bytes (not even UTF-8 structure the parser expects)
    std::fs::write(&path, b"\x00\xffnot a plan\nat all\n").unwrap();
    assert_eq!(tuner::load_cached(&dir, tier, 1), None, "garbage file must be ignored");

    // version-bumped header in an otherwise valid body
    let bumped = good.replacen(
        &format!("v{}", tuner::TUNE_CACHE_VERSION),
        &format!("v{}", tuner::TUNE_CACHE_VERSION + 1),
        1,
    );
    std::fs::write(&path, bumped).unwrap();
    assert_eq!(tuner::load_cached(&dir, tier, 1), None, "version bump must be ignored");

    // out-of-variant-space tile from a hypothetical newer build
    std::fs::write(
        &path,
        format!(
            "hylu-tune-cache v{}\ngemm tiled 6 32 2\npack_a 0\ntrsm 48 8\n",
            tuner::TUNE_CACHE_VERSION
        ),
    )
    .unwrap();
    assert_eq!(tuner::load_cached(&dir, tier, 1), None, "unknown tile must be ignored");

    // and a good file still loads after all that
    std::fs::write(&path, good).unwrap();
    assert_eq!(tuner::load_cached(&dir, tier, 1), Some(plan));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tune_cached_is_memoized_per_pattern() {
    let a = gen::grid2d(24, 24);
    let sym = analyze_pattern(&a, MergePolicy::Exact { max_width: 32 }, 8);
    let tier = kernels::active_tier();
    // timing noise must not let two analyses of one pattern disagree
    let p1 = tuner::tune_cached(&sym, tier, Tuning::Quick, 0xDEAD_BEEF);
    let p2 = tuner::tune_cached(&sym, tier, Tuning::Quick, 0xDEAD_BEEF);
    assert_eq!(p1, p2);
    // Off always short-circuits to the default plan, even when memoized
    assert_eq!(
        tuner::tune_cached(&sym, tier, Tuning::Off, 0xDEAD_BEEF),
        KernelPlan::default()
    );
}

#[test]
fn quick_tuning_end_to_end_is_correct_and_replay_deterministic() {
    let a = gen::grid2d(40, 40);
    let b = gen::rhs_for_ones(&a);
    let vals = a.vals.clone();
    let solver = SolverBuilder::new()
        .repeated()
        .threads(2)
        .tuning(Tuning::Quick)
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let x1 = sys.solve(&b).unwrap();
    let err = x1.iter().map(|v| (v - 1.0).abs()).fold(0.0f64, f64::max);
    assert!(err < 1e-8, "tuned solve drifted: |x-1| = {err:.3e}");
    // warm refactor with identical values must replay bit-identically
    // under the tuned plan (the plan is fixed per analysis)
    sys.refactor(&vals).unwrap();
    let x2 = sys.solve(&b).unwrap();
    for (u, v) in x1.iter().zip(&x2) {
        assert_eq!(u.to_bits(), v.to_bits(), "tuned refactor replay changed bits");
    }
}

#[test]
fn tuned_and_untuned_solvers_agree_numerically() {
    let a = gen::circuit(1500, 3);
    let b = gen::rhs_for_ones(&a);
    let tuned = SolverBuilder::new().tuning(Tuning::Full).build().unwrap();
    let untuned = SolverBuilder::new().build().unwrap();
    let xt = tuned.analyze(&a).unwrap().factor().unwrap().solve(&b).unwrap();
    let xu = untuned.analyze(&a).unwrap().factor().unwrap().solve(&b).unwrap();
    for (t, u) in xt.iter().zip(&xu) {
        assert!(
            (t - u).abs() <= 1e-9 * u.abs().max(1.0),
            "tuned vs untuned diverged: {t:e} vs {u:e}"
        );
    }
}

//! `LinearSystem<Factored>` is an owning value: moving it between
//! threads (what the elastic service does when it migrates a system
//! between shards) must not change a single bit of `refactor`/`solve`
//! behavior. These tests guard the value-move rebalance path.

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn handle_moved_across_threads_solves_bit_identically() {
    let a = gen::power_network(260, 9);
    let b = rhs(a.n, 4);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    // the stay-at-home twin: identical pipeline, never moved
    let home = solver.analyze(&a).unwrap().factor().unwrap();
    let expect = home.solve(&b).unwrap();

    // the traveler: moved through a chain of threads, solving at each hop
    let mut traveler = solver.analyze(&a).unwrap().factor().unwrap();
    for hop in 0..4 {
        traveler = std::thread::scope(|sc| {
            sc.spawn(|| {
                let x = traveler.solve(&b).unwrap();
                assert_eq!(x, expect, "hop {hop}");
                traveler // moved out of the thread again
            })
            .join()
            .unwrap()
        });
    }
    assert_eq!(traveler.solve(&b).unwrap(), expect, "after the last hop");
}

#[test]
fn handle_moved_across_threads_refactors_bit_identically() {
    let a = gen::grid2d(15, 15);
    let b = rhs(a.n, 8);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let mut home = solver.analyze(&a).unwrap().factor().unwrap();
    let mut traveler = solver.analyze(&a).unwrap().factor().unwrap();

    for step in 1..=4u64 {
        let vals: Vec<f64> = a.vals.iter().map(|v| v * (1.0 + 0.3 * step as f64)).collect();
        home.refactor(&vals).unwrap();
        let expect = home.solve(&b).unwrap();
        // refactor + solve happen on a different thread each step
        traveler = std::thread::scope(|sc| {
            sc.spawn(|| {
                let mut t = traveler;
                t.refactor(&vals).unwrap();
                assert_eq!(t.solve(&b).unwrap(), expect, "step {step}");
                t
            })
            .join()
            .unwrap()
        });
        // the factors themselves are bitwise equal, not just the solutions
        let (hf, tf) = (&home.factorization().fac, &traveler.factorization().fac);
        assert_eq!(hf.lvals, tf.lvals, "step {step}");
        assert_eq!(hf.uvals, tf.uvals, "step {step}");
        assert_eq!(hf.diag, tf.diag, "step {step}");
        assert_eq!(hf.pivot_perm, tf.pivot_perm, "step {step}");
    }
}

#[test]
fn service_migration_round_trip_preserves_factor_bits() {
    // register → migrate across every shard → retire: the returned
    // handle's factors are bitwise those of a handle that never moved
    let a = gen::power_network(200, 2);
    let b = rhs(a.n, 12);
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let home = solver.analyze(&a).unwrap().factor().unwrap();
    let expect = home.solve(&b).unwrap();

    let traveler = solver.analyze(&a).unwrap().factor().unwrap();
    let service = SolverService::with_shards(ServiceConfig {
        shards: 3,
        ..ServiceConfig::default()
    })
    .unwrap();
    let id = service.register_on(traveler, 0).unwrap();
    for shard in [1, 2, 0, 2] {
        service.migrate(id, shard).unwrap();
        assert_eq!(service.shard_of(id), Some(shard));
        assert_eq!(
            service.solve(id, b.clone()).unwrap(),
            expect,
            "on shard {shard}"
        );
    }
    let back = service.retire(id).unwrap();
    drop(service);
    assert_eq!(back.solve(&b).unwrap(), expect, "after retire");
    let (hf, bf) = (&home.factorization().fac, &back.factorization().fac);
    assert_eq!(hf.lvals, bf.lvals);
    assert_eq!(hf.uvals, bf.uvals);
    assert_eq!(hf.diag, bf.diag);
    assert_eq!(hf.pivot_perm, bf.pivot_perm);
    // the handle can keep growing the same engine after its travels
    let sibling = back.solver().analyze(&a).unwrap().factor().unwrap();
    assert_eq!(sibling.solve(&b).unwrap(), expect);
}

//! Refactor pattern-guard tests: `Analysis` must reject a matrix whose
//! pattern differs from the analyzed one even when dimension and nnz
//! match (the FNV pattern hash), and a failed `refactor` must leave the
//! existing factors untouched.
//!
//! These tests deliberately stay on the deprecated `(a, an, f)`
//! coordinator API: the guards exist precisely for callers who thread
//! the triple by hand, and the wrappers must keep working. The handle
//! API's equivalents live in `rust/tests/api_handles.rs`.
#![allow(deprecated)]

use hylu::coordinator::{Solver, SolverConfig};
use hylu::sparse::coo::Coo;
use hylu::sparse::csr::Csr;
use hylu::testutil::max_abs_diff;
use hylu::Error;

/// Diagonal 6×6 plus the given off-diagonal positions (same count ⇒ same
/// nnz across variants, different positions ⇒ different pattern).
fn with_offdiag(offdiag: &[(usize, usize)]) -> Csr {
    let n = 6;
    let mut c = Coo::new(n);
    for i in 0..n {
        c.push(i, i, 4.0 + i as f64);
    }
    for &(i, j) in offdiag {
        c.push(i, j, 1.0);
    }
    c.to_csr()
}

#[test]
fn factor_rejects_same_shape_different_pattern() {
    let a1 = with_offdiag(&[(0, 1), (1, 2), (2, 3)]);
    let a2 = with_offdiag(&[(1, 0), (2, 1), (3, 2)]);
    assert_eq!(a1.n, a2.n);
    assert_eq!(a1.nnz(), a2.nnz(), "variants must agree on nnz for the test");
    let solver = Solver::new(SolverConfig::default());
    let an = solver.analyze(&a1).unwrap();
    let err = solver.factor(&a2, &an).unwrap_err();
    assert!(
        matches!(err, Error::Invalid(_)),
        "expected Error::Invalid, got {err:?}"
    );
}

#[test]
fn refactor_rejects_pattern_change_and_preserves_factors() {
    let a1 = with_offdiag(&[(0, 1), (1, 2), (2, 3)]);
    let a2 = with_offdiag(&[(1, 0), (2, 1), (3, 2)]);
    let solver = Solver::new(SolverConfig::default());
    let an = solver.analyze(&a1).unwrap();
    let mut f = solver.factor(&a1, &an).unwrap();

    let xt: Vec<f64> = (0..a1.n).map(|i| i as f64 - 2.0).collect();
    let mut b = vec![0.0; a1.n];
    a1.matvec(&xt, &mut b);
    let x0 = solver.solve(&a1, &an, &f, &b).unwrap();
    assert!(max_abs_diff(&x0, &xt) < 1e-10);

    // refactor with a different-pattern matrix must fail cleanly...
    let err = solver.refactor(&a2, &an, &mut f).unwrap_err();
    assert!(
        matches!(err, Error::Invalid(_)),
        "expected Error::Invalid, got {err:?}"
    );

    // ...and must not have corrupted the stored factors
    let x1 = solver.solve(&a1, &an, &f, &b).unwrap();
    assert_eq!(x0, x1, "factors changed by a rejected refactor");
}

#[test]
fn refactor_rejects_dimension_and_nnz_mismatch() {
    let a1 = with_offdiag(&[(0, 1)]);
    let solver = Solver::new(SolverConfig::default());
    let an = solver.analyze(&a1).unwrap();
    let mut f = solver.factor(&a1, &an).unwrap();
    // extra nonzero: same n, different nnz
    let more = with_offdiag(&[(0, 1), (3, 4)]);
    assert!(solver.refactor(&more, &an, &mut f).is_err());
    // different dimension entirely
    let mut c = Coo::new(5);
    for i in 0..5 {
        c.push(i, i, 1.0);
    }
    assert!(solver.refactor(&c.to_csr(), &an, &mut f).is_err());
}

/// Two analyses of *same-pattern* matrices can carry different
/// permutations (MC64 weighs values), so the engine's cached permuted
/// matrix must be keyed per analysis — interleaving factors against two
/// analyses on one solver must never reuse the other's permuted structure.
#[test]
fn interleaved_same_pattern_analyses_do_not_poison_the_cache() {
    let build = |d00: f64, d01: f64, d10: f64, d11: f64| {
        let mut c = Coo::new(3);
        c.push(0, 0, d00);
        c.push(0, 1, d01);
        c.push(1, 0, d10);
        c.push(1, 1, d11);
        c.push(2, 2, 1.0);
        c.to_csr()
    };
    // a1 drives MC64 to the anti-diagonal matching, a2 to the diagonal —
    // identical pattern (and pattern hash), different row permutations
    let a1 = build(1e-6, 2.0, 3.0, 1e-6);
    let a2 = build(2.0, 1e-6, 1e-6, 3.0);
    let solver = Solver::new(SolverConfig::default());
    let an1 = solver.analyze(&a1).unwrap();
    let an2 = solver.analyze(&a2).unwrap();
    let xt = [1.0, -2.0, 3.0];
    let check = |a: &Csr, an: &hylu::coordinator::Analysis| {
        let f = solver.factor(a, an).unwrap();
        let mut b = vec![0.0; 3];
        a.matvec(&xt, &mut b);
        let x = solver.solve(a, an, &f, &b).unwrap();
        assert!(
            max_abs_diff(&x, &xt) < 1e-8,
            "stale permuted-matrix cache: err {}",
            max_abs_diff(&x, &xt)
        );
    };
    // interleave so each factor call sees the other analysis' cache entry
    check(&a1, &an1);
    check(&a2, &an2);
    check(&a1, &an1);
}

#[test]
fn refactor_accepts_same_pattern_new_values() {
    let a1 = with_offdiag(&[(0, 1), (1, 2), (2, 3)]);
    let solver = Solver::new(SolverConfig::default());
    let an = solver.analyze(&a1).unwrap();
    let mut f = solver.factor(&a1, &an).unwrap();
    let mut a2 = a1.clone();
    for v in &mut a2.vals {
        *v *= 1.5;
    }
    solver.refactor(&a2, &an, &mut f).unwrap();
    let xt: Vec<f64> = (0..a2.n).map(|i| (i % 3) as f64 + 1.0).collect();
    let mut b = vec![0.0; a2.n];
    a2.matvec(&xt, &mut b);
    let x = solver.solve(&a2, &an, &f, &b).unwrap();
    assert!(max_abs_diff(&x, &xt) < 1e-9);
}

//! Concurrency tests for the serving stack: N threads hammering one
//! `Solver` (scratch checkout pool) and one `SolverService` (coalescing
//! queue + elastic topology), asserting bit-identical results vs.
//! sequential solves, no deadlock, coalescing of k > 1 right-hand sides
//! per dispatch, and live register/retire/migrate semantics.

use std::time::{Duration, Instant};

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs_set(n: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect()
}

#[test]
fn threads_hammering_one_system_match_sequential_bitwise() {
    let a = gen::grid2d(20, 20);
    let solver = SolverBuilder::new()
        .threads(2)
        .scratch_slots(8)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let bs = rhs_set(a.n, 8, 21);
    // sequential references first
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| sys.solve(b).unwrap()).collect();
    std::thread::scope(|sc| {
        for t in 0..8usize {
            let (sys, bs, expect) = (&sys, &bs, &expect);
            sc.spawn(move || {
                for rep in 0..10 {
                    let q = (t + rep) % bs.len();
                    let x = sys.solve(&bs[q]).unwrap();
                    assert_eq!(x, expect[q], "thread {t} rep {rep} col {q}");
                }
            });
        }
    });
    // every slot went back to the pool
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);
}

#[test]
fn solver_with_one_scratch_slot_still_serves_concurrent_callers() {
    // cap 1 forces callers through the condvar fallback path: correctness
    // and liveness must hold even fully contended
    let a = gen::grid2d(12, 12);
    let solver = SolverBuilder::new()
        .threads(1)
        .scratch_slots(1)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let expect = sys.solve(&b).unwrap();
    std::thread::scope(|sc| {
        for _ in 0..6 {
            let (sys, b, expect) = (&sys, &b, &expect);
            sc.spawn(move || {
                for _ in 0..20 {
                    assert_eq!(sys.solve(b).unwrap(), *expect);
                }
            });
        }
    });
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);
}

fn service_cfg(shards: usize, tick_ms: u64) -> ServiceConfig {
    ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        max_batch: 64,
        queue_cap: 4096,
        tick: Duration::from_millis(tick_ms),
        ..ServiceConfig::default()
    }
}

#[test]
fn service_coalesces_and_matches_sequential_bitwise() {
    let a = gen::grid2d(40, 40);
    let service = SolverService::new(service_cfg(1, 2), vec![a.clone()]).unwrap();
    // identically configured standalone solver: the deterministic
    // pipeline produces the same analysis/factors, so results must be
    // bit-identical to the service's batched columns
    let reference = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let bs = rhs_set(a.n, 48, 7);
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| reference.solve(b).unwrap()).collect();
    // submit everything up front: the 2ms coalescing tick piles the
    // whole burst into very few dispatches
    let tickets: Vec<_> = bs
        .iter()
        .map(|b| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();
    for (q, ticket) in tickets.into_iter().enumerate() {
        let x = ticket.wait().unwrap();
        assert_eq!(x, expect[q], "column {q}");
    }
    let st = service.stats();
    assert_eq!(st.requests, 48);
    assert_eq!(st.rhs_solved, 48);
    assert!(
        st.max_batch > 1,
        "burst of 48 must coalesce: max batch {}",
        st.max_batch
    );
    assert!(
        st.mean_batch() > 1.0,
        "mean batch {} must exceed 1",
        st.mean_batch()
    );
    assert!(st.dispatches < 48, "dispatches {}", st.dispatches);
}

#[test]
fn sharded_multi_system_service_with_concurrent_callers() {
    // four same-size systems with different values across two shards
    let base = gen::power_network(300, 7);
    let systems: Vec<Csr> = (0..4)
        .map(|s| {
            let mut m = base.clone();
            for v in &mut m.vals {
                *v *= 1.0 + 0.2 * s as f64;
            }
            m
        })
        .collect();
    let service = SolverService::new(service_cfg(2, 1), systems.clone()).unwrap();
    assert_eq!(service.shard_count(), 2);
    assert_eq!(service.system_count(), 4);
    assert_eq!(
        service.system_ids(),
        (0..4).map(SystemId).collect::<Vec<_>>(),
        "construction ids are assigned in order"
    );
    // references from an identically configured solver
    let reference = SolverBuilder::new().threads(1).build().unwrap();
    let bs = rhs_set(base.n, 4, 3);
    let mut expect = Vec::new();
    for (s, m) in systems.iter().enumerate() {
        let sys = reference.analyze(m).unwrap().factor().unwrap();
        expect.push(sys.solve(&bs[s]).unwrap());
    }
    std::thread::scope(|sc| {
        for t in 0..6usize {
            let (service, bs, expect) = (&service, &bs, &expect);
            sc.spawn(move || {
                for rep in 0..8 {
                    let sys = (t + rep) % 4;
                    let x = service.solve(SystemId(sys as u64), bs[sys].clone()).unwrap();
                    assert_eq!(x, expect[sys], "thread {t} sys {sys}");
                }
            });
        }
    });
}

#[test]
fn service_refactor_updates_results() {
    let a = gen::grid2d(15, 15);
    let service = SolverService::new(service_cfg(1, 0), vec![a.clone()]).unwrap();
    let b = gen::rhs_for_ones(&a);
    let x = service.solve(SystemId(0), b.clone()).unwrap();
    let err: f64 = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    assert!(err < 1e-8, "initial solve err {err}");
    // sweep step: double every value; same rhs now solves to 0.5
    let mut a2 = a.clone();
    for v in &mut a2.vals {
        *v *= 2.0;
    }
    service.refactor(SystemId(0), a2).unwrap();
    let x2 = service.solve(SystemId(0), b).unwrap();
    let err2: f64 = x2.iter().map(|v| (v - 0.5).abs()).fold(0.0, f64::max);
    assert!(err2 < 1e-8, "post-refactor err {err2}");
    assert_eq!(service.stats().refactors, 1);
}

#[test]
fn service_drop_resolves_all_pending_tickets() {
    let a = gen::grid2d(30, 30);
    let b = gen::rhs_for_ones(&a);
    let service = SolverService::new(service_cfg(1, 5), vec![a.clone()]).unwrap();
    let tickets: Vec<_> = (0..16)
        .map(|_| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();
    // dropping the service drains the queue before joining the
    // dispatcher: every accepted ticket must still resolve
    drop(service);
    for t in tickets {
        let x = t.wait().unwrap();
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
    }
}

#[test]
fn service_rejects_bad_requests() {
    let a = gen::grid2d(8, 8);
    let service = SolverService::new(ServiceConfig::default(), vec![a.clone()]).unwrap();
    assert!(
        service.submit(SystemId(1), vec![0.0; a.n]).is_err(),
        "unknown system"
    );
    assert!(
        service.submit(SystemId(0), vec![0.0; 3]).is_err(),
        "bad rhs length"
    );
    let mut wrong = gen::grid2d(8, 9);
    wrong.vals.iter_mut().for_each(|v| *v *= 2.0);
    assert!(service.refactor(SystemId(0), wrong).is_err(), "dimension mismatch");
    assert!(
        SolverService::new(ServiceConfig::default(), vec![]).is_err(),
        "no systems"
    );
}

#[test]
fn register_and_retire_on_a_live_service() {
    let a = gen::grid2d(14, 14);
    let service = SolverService::with_shards(service_cfg(2, 0)).unwrap();
    assert_eq!(service.system_count(), 0);
    let epoch0 = service.route_epoch();

    // register: the handle is analyzed/factored outside the service and
    // moves in as a value; solving through the service must be
    // bit-identical to solving on the handle before it moved
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let expect = sys.solve(&b).unwrap();
    let id = service.register(sys).unwrap();
    assert_eq!(service.system_count(), 1);
    assert!(service.route_epoch() > epoch0, "register publishes an epoch");
    assert_eq!(service.solve(id, b.clone()).unwrap(), expect);

    // retire hands the owning handle back; it keeps solving bit-identically
    let back = service.retire(id).unwrap();
    assert_eq!(service.system_count(), 0);
    assert_eq!(back.solve(&b).unwrap(), expect);

    // the retired id is gone for good
    assert!(service.submit(id, b.clone()).is_err(), "retired id rejected");
    assert!(service.shard_of(id).is_none());

    // ids are never reused
    let sys2 = solver.analyze(&a).unwrap().factor().unwrap();
    let id2 = service.register(sys2).unwrap();
    assert_ne!(id2, id);
    let _ = service.retire(id2).unwrap();
}

#[test]
fn retire_drains_in_flight_tickets_first() {
    let a = gen::grid2d(25, 25);
    let b = gen::rhs_for_ones(&a);
    // a 5ms tick holds the burst in the queue long enough for retire to
    // land behind it
    let service = SolverService::new(service_cfg(1, 5), vec![a.clone()]).unwrap();
    let tickets: Vec<_> = (0..12)
        .map(|_| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();
    let handle = service.retire(SystemId(0)).unwrap();
    // every ticket admitted before the retire resolved with a solution
    for t in tickets {
        let x = t.wait().unwrap();
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
    }
    assert_eq!(handle.n(), a.n);
}

#[test]
fn migrate_under_traffic_is_bit_identical() {
    let a = gen::power_network(240, 3);
    let service = SolverService::new(service_cfg(2, 0), vec![a.clone()]).unwrap();
    let reference = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let bs = rhs_set(a.n, 6, 11);
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| reference.solve(b).unwrap()).collect();
    let id = SystemId(0);
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|sc| {
        for t in 0..4usize {
            let (service, bs, expect, done) = (&service, &bs, &expect, &done);
            sc.spawn(move || {
                let mut rep = 0usize;
                while !done.load(std::sync::atomic::Ordering::Relaxed) || rep < 10 {
                    let q = (t + rep) % bs.len();
                    let x = service.solve(id, bs[q].clone()).unwrap();
                    assert_eq!(x, expect[q], "thread {t} rep {rep}");
                    rep += 1;
                    if rep > 400 {
                        break; // safety valve
                    }
                }
            });
        }
        // bounce the system between shards while the callers hammer it
        for round in 0..20 {
            service.migrate(id, round % 2).unwrap();
        }
        done.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    let st = service.stats();
    assert_eq!(st.moves, 19, "19 of 20 bounces actually moved (first is a no-op)");
}

#[test]
fn rebalance_moves_hot_systems_off_a_loaded_shard() {
    let a = gen::grid2d(16, 16);
    let b = gen::rhs_for_ones(&a);
    // both systems forced onto shard 0 of 2: shard 1 starts idle
    let service = SolverService::with_shards(service_cfg(2, 0)).unwrap();
    let solver = SolverBuilder::new().threads(1).build().unwrap();
    let s0 = solver.analyze(&a).unwrap().factor().unwrap();
    let s1 = solver.analyze(&a).unwrap().factor().unwrap();
    let id0 = service.register_on(s0, 0).unwrap();
    let id1 = service.register_on(s1, 0).unwrap();
    assert_eq!(service.shard_of(id0), Some(0));
    assert_eq!(service.shard_of(id1), Some(0));
    // drive traffic so both systems accumulate EWMA load
    for _ in 0..30 {
        service.solve(id0, b.clone()).unwrap();
        service.solve(id1, b.clone()).unwrap();
    }
    assert!(
        service.system_load(id0).unwrap().ewma > 0.0,
        "traffic must register in the EWMA"
    );
    let moved = service.rebalance().unwrap();
    assert!(moved >= 1, "an all-on-one placement must rebalance");
    let shards = [service.shard_of(id0).unwrap(), service.shard_of(id1).unwrap()];
    assert_ne!(shards[0], shards[1], "systems spread across shards");
    // traffic still serves correctly after the move
    let x = service.solve(id0, b.clone()).unwrap();
    assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
    assert_eq!(service.stats().moves as usize, moved);
}

#[test]
fn deadline_lane_dispatches_before_bulk() {
    let a = gen::grid2d(20, 20);
    let b = gen::rhs_for_ones(&a);
    // a long tick holds one drain window open while both lanes fill
    let cfg = ServiceConfig {
        tick: Duration::from_millis(10),
        max_batch: 4,
        ..service_cfg(1, 10)
    };
    let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
    let bulk: Vec<_> = (0..6)
        .map(|_| service.submit(SystemId(0), b.clone()).unwrap())
        .collect();
    let urgent = service
        .submit_with(
            SystemId(0),
            b.clone(),
            Priority::Deadline(Instant::now() + Duration::from_millis(1)),
        )
        .unwrap();
    // all resolve, bit-identically
    let xu = urgent.wait().unwrap();
    for t in bulk {
        assert_eq!(t.wait().unwrap(), xu);
    }
    let st = service.stats();
    assert_eq!(st.requests, 7);
    assert_eq!(st.deadline_requests, 1);
}

#[test]
fn adaptive_tick_stays_bounded_and_batches() {
    let a = gen::grid2d(24, 24);
    let b = gen::rhs_for_ones(&a);
    let cfg = ServiceConfig {
        tick: Duration::from_micros(100),
        tick_max: Duration::from_millis(2),
        ..service_cfg(1, 0)
    };
    let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
    // sustained concurrent bursts: the window should stretch and coalesce
    std::thread::scope(|sc| {
        for _ in 0..4 {
            let (service, b) = (&service, &b);
            sc.spawn(move || {
                for _ in 0..30 {
                    let x = service.solve(SystemId(0), b.clone()).unwrap();
                    assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
                }
            });
        }
    });
    let st = service.stats();
    assert_eq!(st.rhs_solved, 120);
    assert!(
        st.max_tick <= Duration::from_millis(2),
        "adaptive window {:?} exceeded tick_max",
        st.max_tick
    );
}

#[test]
fn empty_elastic_service_shuts_down_cleanly() {
    let service = SolverService::with_shards(service_cfg(4, 0)).unwrap();
    assert_eq!(service.shard_count(), 4);
    assert_eq!(service.system_count(), 0);
    assert_eq!(service.stats().requests, 0);
    drop(service); // joins 4 idle dispatchers without work
}

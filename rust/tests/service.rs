//! Concurrency tests for the serving stack: N threads hammering one
//! `Solver` (scratch checkout pool) and one `SolverService` (coalescing
//! queue), asserting bit-identical results vs. sequential solves, no
//! deadlock, and that coalescing actually batches k > 1 right-hand
//! sides per dispatch.

use std::time::Duration;

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs_set(n: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect()
}

#[test]
fn threads_hammering_one_system_match_sequential_bitwise() {
    let a = gen::grid2d(20, 20);
    let solver = SolverBuilder::new()
        .threads(2)
        .scratch_slots(8)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let bs = rhs_set(a.n, 8, 21);
    // sequential references first
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| sys.solve(b).unwrap()).collect();
    std::thread::scope(|sc| {
        for t in 0..8usize {
            let (sys, bs, expect) = (&sys, &bs, &expect);
            sc.spawn(move || {
                for rep in 0..10 {
                    let q = (t + rep) % bs.len();
                    let x = sys.solve(&bs[q]).unwrap();
                    assert_eq!(x, expect[q], "thread {t} rep {rep} col {q}");
                }
            });
        }
    });
    // every slot went back to the pool
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);
}

#[test]
fn solver_with_one_scratch_slot_still_serves_concurrent_callers() {
    // cap 1 forces callers through the condvar fallback path: correctness
    // and liveness must hold even fully contended
    let a = gen::grid2d(12, 12);
    let solver = SolverBuilder::new()
        .threads(1)
        .scratch_slots(1)
        .build()
        .unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let expect = sys.solve(&b).unwrap();
    std::thread::scope(|sc| {
        for _ in 0..6 {
            let (sys, b, expect) = (&sys, &b, &expect);
            sc.spawn(move || {
                for _ in 0..20 {
                    assert_eq!(sys.solve(b).unwrap(), *expect);
                }
            });
        }
    });
    assert_eq!(solver.engine().scratch_pool().in_use(), 0);
}

fn service_cfg(shards: usize, tick_ms: u64) -> ServiceConfig {
    ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        max_batch: 64,
        queue_cap: 4096,
        tick: Duration::from_millis(tick_ms),
    }
}

#[test]
fn service_coalesces_and_matches_sequential_bitwise() {
    let a = gen::grid2d(40, 40);
    let service = SolverService::new(service_cfg(1, 2), vec![a.clone()]).unwrap();
    // identically configured standalone solver: the deterministic
    // pipeline produces the same analysis/factors, so results must be
    // bit-identical to the service's batched columns
    let reference = SolverBuilder::new()
        .threads(1)
        .build()
        .unwrap()
        .analyze(&a)
        .unwrap()
        .factor()
        .unwrap();
    let bs = rhs_set(a.n, 48, 7);
    let expect: Vec<Vec<f64>> = bs.iter().map(|b| reference.solve(b).unwrap()).collect();
    // submit everything up front: the 2ms coalescing tick piles the
    // whole burst into very few dispatches
    let tickets: Vec<_> = bs
        .iter()
        .map(|b| service.submit(0, b.clone()).unwrap())
        .collect();
    for (q, ticket) in tickets.into_iter().enumerate() {
        let x = ticket.wait().unwrap();
        assert_eq!(x, expect[q], "column {q}");
    }
    let st = service.stats();
    assert_eq!(st.requests, 48);
    assert_eq!(st.rhs_solved, 48);
    assert!(
        st.max_batch > 1,
        "burst of 48 must coalesce: max batch {}",
        st.max_batch
    );
    assert!(
        st.mean_batch() > 1.0,
        "mean batch {} must exceed 1",
        st.mean_batch()
    );
    assert!(st.dispatches < 48, "dispatches {}", st.dispatches);
}

#[test]
fn sharded_multi_system_service_with_concurrent_callers() {
    // four same-size systems with different values across two shards
    let base = gen::power_network(300, 7);
    let systems: Vec<Csr> = (0..4)
        .map(|s| {
            let mut m = base.clone();
            for v in &mut m.vals {
                *v *= 1.0 + 0.2 * s as f64;
            }
            m
        })
        .collect();
    let service = SolverService::new(service_cfg(2, 1), systems.clone()).unwrap();
    assert_eq!(service.shard_count(), 2);
    assert_eq!(service.system_count(), 4);
    // references from an identically configured solver
    let reference = SolverBuilder::new().threads(1).build().unwrap();
    let bs = rhs_set(base.n, 4, 3);
    let mut expect = Vec::new();
    for (s, m) in systems.iter().enumerate() {
        let sys = reference.analyze(m).unwrap().factor().unwrap();
        expect.push(sys.solve(&bs[s]).unwrap());
    }
    std::thread::scope(|sc| {
        for t in 0..6usize {
            let (service, bs, expect) = (&service, &bs, &expect);
            sc.spawn(move || {
                for rep in 0..8 {
                    let sys = (t + rep) % 4;
                    let x = service.solve(sys, bs[sys].clone()).unwrap();
                    assert_eq!(x, expect[sys], "thread {t} sys {sys}");
                }
            });
        }
    });
}

#[test]
fn service_refactor_updates_results() {
    let a = gen::grid2d(15, 15);
    let service = SolverService::new(service_cfg(1, 0), vec![a.clone()]).unwrap();
    let b = gen::rhs_for_ones(&a);
    let x = service.solve(0, b.clone()).unwrap();
    let err: f64 = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    assert!(err < 1e-8, "initial solve err {err}");
    // sweep step: double every value; same rhs now solves to 0.5
    let mut a2 = a.clone();
    for v in &mut a2.vals {
        *v *= 2.0;
    }
    service.refactor(0, a2).unwrap();
    let x2 = service.solve(0, b).unwrap();
    let err2: f64 = x2.iter().map(|v| (v - 0.5).abs()).fold(0.0, f64::max);
    assert!(err2 < 1e-8, "post-refactor err {err2}");
    assert_eq!(service.stats().refactors, 1);
}

#[test]
fn service_drop_resolves_all_pending_tickets() {
    let a = gen::grid2d(30, 30);
    let b = gen::rhs_for_ones(&a);
    let service = SolverService::new(service_cfg(1, 5), vec![a.clone()]).unwrap();
    let tickets: Vec<_> = (0..16)
        .map(|_| service.submit(0, b.clone()).unwrap())
        .collect();
    // dropping the service drains the queue before joining the
    // dispatcher: every accepted ticket must still resolve
    drop(service);
    for t in tickets {
        let x = t.wait().unwrap();
        assert!(x.iter().all(|v| (v - 1.0).abs() < 1e-7));
    }
}

#[test]
fn service_rejects_bad_requests() {
    let a = gen::grid2d(8, 8);
    let service = SolverService::new(ServiceConfig::default(), vec![a.clone()]).unwrap();
    assert!(service.submit(1, vec![0.0; a.n]).is_err(), "unknown system");
    assert!(service.submit(0, vec![0.0; 3]).is_err(), "bad rhs length");
    let mut wrong = gen::grid2d(8, 9);
    wrong.vals.iter_mut().for_each(|v| *v *= 2.0);
    assert!(service.refactor(0, wrong).is_err(), "dimension mismatch");
    assert!(
        SolverService::new(ServiceConfig::default(), vec![]).is_err(),
        "no systems"
    );
}

//! Persistent-engine integration tests: after one warm-up cycle, a
//! `refactor` + `solve` (and `solve_many`) cycle must spawn zero OS
//! threads and perform zero O(n) scratch allocations — asserted through
//! the engine's spawn/alloc counters — and the batched multi-RHS path
//! must match independent scalar solves bit-for-bit. Runs entirely on
//! the `LinearSystem` handle API, so the zero-spawn / zero-alloc
//! guarantees are asserted for the surface users actually call.

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs_set(n: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect()
}

#[test]
fn analyze_only_paths_spawn_no_threads() {
    // lazy pool spawn: `hylu inspect` / fig4-style analyze-only use must
    // never pay for worker threads; the first numeric dispatch spawns
    let a = gen::grid2d(12, 12);
    let solver = SolverBuilder::new().threads(4).build().unwrap();
    assert_eq!(solver.engine().threads_spawned(), 0, "construction spawns nothing");
    let sys = solver.analyze(&a).unwrap();
    assert_eq!(solver.engine().threads_spawned(), 0, "analyze spawns nothing");
    let _sys = sys.factor().unwrap();
    assert_eq!(
        solver.engine().threads_spawned(),
        3,
        "first numeric dispatch spawns threads-1 workers"
    );
}

#[test]
fn warm_refactor_solve_cycle_spawns_nothing_and_allocates_nothing() {
    let a = gen::grid2d(24, 24);
    let solver = SolverBuilder::new()
        .repeated()
        .threads(3)
        .configure(|cfg| cfg.parallel_solve_min_n = 0) // force the pooled substitution path
        .build()
        .unwrap();
    let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let bs = rhs_set(a.n, 3, 11);
    let mut x = Vec::new();
    let mut xs = Vec::new();

    // Warm-up: one full refactor + solve + solve_many cycle grows every
    // arena to its high-water mark.
    sys.refactor(&a.vals).unwrap();
    sys.solve_into(&b, &mut x).unwrap();
    sys.solve_many_into(&bs, &mut xs).unwrap();

    let spawned = solver.engine().threads_spawned();
    let allocs = solver.engine().scratch_alloc_events();
    assert_eq!(spawned, 2, "pool of 3 spawns exactly 2 OS threads");

    // Warm cycles: identical inputs exercise the identical code path; the
    // counters must not move at all.
    for _ in 0..3 {
        sys.refactor(&a.vals).unwrap();
        let st = sys.solve_into(&b, &mut x).unwrap();
        assert!(st.residual < 1e-10, "residual {}", st.residual);
        sys.solve_many_into(&bs, &mut xs).unwrap();
    }
    assert_eq!(
        solver.engine().threads_spawned(),
        spawned,
        "warm cycles must spawn no OS threads"
    );
    assert_eq!(
        solver.engine().scratch_alloc_events(),
        allocs,
        "warm cycles must not grow any scratch arena"
    );
}

#[test]
fn warm_cycle_is_allocation_free_for_all_kernel_modes() {
    let a = gen::grid2d(16, 16);
    for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
        let solver = SolverBuilder::new()
            .threads(2)
            .kernel(mode)
            .configure(|cfg| cfg.parallel_solve_min_n = 0)
            .build()
            .unwrap();
        let mut sys = solver.analyze(&a).unwrap().factor().unwrap();
        let b = gen::rhs_for_ones(&a);
        let mut x = Vec::new();
        sys.refactor(&a.vals).unwrap();
        sys.solve_into(&b, &mut x).unwrap();
        let spawned = solver.engine().threads_spawned();
        let allocs = solver.engine().scratch_alloc_events();
        for _ in 0..2 {
            sys.refactor(&a.vals).unwrap();
            sys.solve_into(&b, &mut x).unwrap();
        }
        assert_eq!(solver.engine().threads_spawned(), spawned, "{mode}");
        assert_eq!(solver.engine().scratch_alloc_events(), allocs, "{mode}");
    }
}

#[test]
fn solve_many_matches_independent_solves_bitwise() {
    for (a, seed) in [
        (gen::power_network(300, 7), 3u64),
        (gen::grid2d(18, 18), 4),
        (gen::kkt(150, 50, 3), 5), // perturbation → refinement engages
    ] {
        for threads in [1usize, 3] {
            let solver = SolverBuilder::new()
                .threads(threads)
                .configure(|cfg| cfg.parallel_solve_min_n = 0)
                .build()
                .unwrap();
            let sys = solver.analyze(&a).unwrap().factor().unwrap();
            let bs = rhs_set(a.n, 5, seed);
            let xs = sys.solve_many(&bs).unwrap();
            assert_eq!(xs.len(), bs.len());
            for (q, b) in bs.iter().enumerate() {
                let x = sys.solve(b).unwrap();
                assert_eq!(
                    xs[q], x,
                    "batched column {q} must be bit-identical (t={threads})"
                );
            }
        }
    }
}

#[test]
fn solve_many_k1_matches_scalar_solve() {
    let a = gen::circuit(400, 2);
    let solver = SolverBuilder::new().build().unwrap();
    let sys = solver.analyze(&a).unwrap().factor().unwrap();
    let b = gen::rhs_for_ones(&a);
    let xs = sys.solve_many(&[b.clone()]).unwrap();
    let x = sys.solve(&b).unwrap();
    assert_eq!(xs[0], x);
}

#[test]
fn analysis_plan_matches_pool_width() {
    let a = gen::grid2d(10, 10);
    for threads in [1usize, 2, 5] {
        let solver = SolverBuilder::new().threads(threads).build().unwrap();
        let sys = solver.analyze(&a).unwrap();
        let an = sys.analysis();
        assert_eq!(an.plan.nthreads, solver.engine().pool().nthreads());
        assert_eq!(an.plan.factor_chunks.len(), an.sym.schedule.bulk_levels);
    }
}

#[test]
fn alternating_two_analyses_stays_allocation_free_when_warm() {
    // one solver serving two systems per tick: both permuted-matrix cache
    // entries (and the shared done-flag/workspace arenas) must stay warm
    let a1 = gen::grid2d(14, 14);
    let a2 = gen::power_network(200, 5);
    let solver = SolverBuilder::new()
        .threads(2)
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .unwrap();
    let mut s1 = solver.analyze(&a1).unwrap().factor().unwrap();
    let mut s2 = solver.analyze(&a2).unwrap().factor().unwrap();
    let b1 = gen::rhs_for_ones(&a1);
    let b2 = gen::rhs_for_ones(&a2);
    let (mut x1, mut x2) = (Vec::new(), Vec::new());
    // warm-up tick for both systems
    s1.refactor(&a1.vals).unwrap();
    s1.solve_into(&b1, &mut x1).unwrap();
    s2.refactor(&a2.vals).unwrap();
    s2.solve_into(&b2, &mut x2).unwrap();
    let spawned = solver.engine().threads_spawned();
    let allocs = solver.engine().scratch_alloc_events();
    for _ in 0..3 {
        s1.refactor(&a1.vals).unwrap();
        s1.solve_into(&b1, &mut x1).unwrap();
        s2.refactor(&a2.vals).unwrap();
        s2.solve_into(&b2, &mut x2).unwrap();
    }
    assert_eq!(solver.engine().threads_spawned(), spawned);
    assert_eq!(
        solver.engine().scratch_alloc_events(),
        allocs,
        "alternating warm systems must not re-clone the permuted cache"
    );
}

#[test]
fn engine_survives_many_analyses_and_mixed_sizes() {
    // switching between systems of different size on one engine must stay
    // correct (arenas are high-water sized, larger n regrows them)
    let solver = SolverBuilder::new()
        .threads(2)
        .configure(|cfg| cfg.parallel_solve_min_n = 0)
        .build()
        .unwrap();
    for a in [gen::grid2d(8, 8), gen::grid2d(20, 20), gen::grid2d(5, 5)] {
        let sys = solver.analyze(&a).unwrap().factor().unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 6) as f64 - 2.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let x = sys.solve(&b).unwrap();
        let err = hylu::testutil::max_abs_diff(&x, &xt);
        assert!(err < 1e-8, "n={} err={err}", a.n);
    }
}

#[test]
fn handles_share_one_engine_across_clones() {
    // a cloned Solver shares the engine: systems analyzed through either
    // clone dispatch onto the same pool (one spawn event total)
    let a = gen::grid2d(10, 10);
    let solver = SolverBuilder::new().threads(2).build().unwrap();
    let clone = solver.clone();
    let s1 = solver.analyze(&a).unwrap().factor().unwrap();
    let spawned = solver.engine().threads_spawned();
    let s2 = clone.analyze(&a).unwrap().factor().unwrap();
    assert_eq!(
        clone.engine().threads_spawned(),
        spawned,
        "second handle must reuse the already-spawned pool"
    );
    let b = gen::rhs_for_ones(&a);
    assert_eq!(s1.solve(&b).unwrap(), s2.solve(&b).unwrap());
}

// keep the raw-config path compiling too: SolverConfig is still the
// underlying configuration carrier for services and baselines
#[test]
fn from_config_matches_builder() {
    let a = gen::grid2d(9, 9);
    let cfg = SolverConfig {
        threads: 1,
        repeated: true,
        ..SolverConfig::default()
    };
    let s1 = Solver::from_config(cfg).unwrap();
    let s2 = SolverBuilder::new().repeated().threads(1).build().unwrap();
    let b = gen::rhs_for_ones(&a);
    let x1 = s1.analyze(&a).unwrap().factor().unwrap().solve(&b).unwrap();
    let x2 = s2.analyze(&a).unwrap().factor().unwrap().solve(&b).unwrap();
    assert_eq!(x1, x2);
}

//! Persistent-engine integration tests: after one warm-up cycle, a
//! `refactor` + `solve` (and `solve_many`) cycle must spawn zero OS
//! threads and perform zero O(n) scratch allocations — asserted through
//! the engine's spawn/alloc counters — and the batched multi-RHS path
//! must match independent scalar solves bit-for-bit.

use hylu::coordinator::{Solver, SolverConfig};
use hylu::sparse::gen;
use hylu::testutil::Prng;

fn rhs_set(n: usize, k: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Prng::new(seed);
    (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect()
}

#[test]
fn analyze_only_paths_spawn_no_threads() {
    // lazy pool spawn: `hylu inspect` / fig4-style analyze-only use must
    // never pay for worker threads; the first numeric dispatch spawns
    let a = gen::grid2d(12, 12);
    let solver = Solver::new(SolverConfig {
        threads: 4,
        ..SolverConfig::default()
    });
    assert_eq!(solver.engine().threads_spawned(), 0, "construction spawns nothing");
    let an = solver.analyze(&a).unwrap();
    assert_eq!(solver.engine().threads_spawned(), 0, "analyze spawns nothing");
    let _f = solver.factor(&a, &an).unwrap();
    assert_eq!(
        solver.engine().threads_spawned(),
        3,
        "first numeric dispatch spawns threads-1 workers"
    );
}

#[test]
fn warm_refactor_solve_cycle_spawns_nothing_and_allocates_nothing() {
    let a = gen::grid2d(24, 24);
    let solver = Solver::new(SolverConfig {
        threads: 3,
        repeated: true,
        parallel_solve_min_n: 0, // force the pooled substitution path
        ..SolverConfig::default()
    });
    let an = solver.analyze(&a).unwrap();
    let mut f = solver.factor(&a, &an).unwrap();
    let b = gen::rhs_for_ones(&a);
    let bs = rhs_set(a.n, 3, 11);
    let mut x = Vec::new();
    let mut xs = Vec::new();

    // Warm-up: one full refactor + solve + solve_many cycle grows every
    // arena to its high-water mark.
    solver.refactor(&a, &an, &mut f).unwrap();
    solver.solve_into(&a, &an, &f, &b, &mut x).unwrap();
    solver.solve_many_into(&a, &an, &f, &bs, &mut xs).unwrap();

    let spawned = solver.engine().threads_spawned();
    let allocs = solver.engine().scratch_alloc_events();
    assert_eq!(spawned, 2, "pool of 3 spawns exactly 2 OS threads");

    // Warm cycles: identical inputs exercise the identical code path; the
    // counters must not move at all.
    for _ in 0..3 {
        solver.refactor(&a, &an, &mut f).unwrap();
        let st = solver.solve_into(&a, &an, &f, &b, &mut x).unwrap();
        assert!(st.residual < 1e-10, "residual {}", st.residual);
        solver.solve_many_into(&a, &an, &f, &bs, &mut xs).unwrap();
    }
    assert_eq!(
        solver.engine().threads_spawned(),
        spawned,
        "warm cycles must spawn no OS threads"
    );
    assert_eq!(
        solver.engine().scratch_alloc_events(),
        allocs,
        "warm cycles must not grow any scratch arena"
    );
}

#[test]
fn warm_cycle_is_allocation_free_for_all_kernel_modes() {
    use hylu::numeric::select::KernelMode;
    let a = gen::grid2d(16, 16);
    for mode in [KernelMode::RowRow, KernelMode::SupRow, KernelMode::SupSup] {
        let solver = Solver::new(SolverConfig {
            threads: 2,
            kernel: Some(mode),
            parallel_solve_min_n: 0,
            ..SolverConfig::default()
        });
        let an = solver.analyze(&a).unwrap();
        let mut f = solver.factor(&a, &an).unwrap();
        let b = gen::rhs_for_ones(&a);
        let mut x = Vec::new();
        solver.refactor(&a, &an, &mut f).unwrap();
        solver.solve_into(&a, &an, &f, &b, &mut x).unwrap();
        let spawned = solver.engine().threads_spawned();
        let allocs = solver.engine().scratch_alloc_events();
        for _ in 0..2 {
            solver.refactor(&a, &an, &mut f).unwrap();
            solver.solve_into(&a, &an, &f, &b, &mut x).unwrap();
        }
        assert_eq!(solver.engine().threads_spawned(), spawned, "{mode}");
        assert_eq!(solver.engine().scratch_alloc_events(), allocs, "{mode}");
    }
}

#[test]
fn solve_many_matches_independent_solves_bitwise() {
    for (a, seed) in [
        (gen::power_network(300, 7), 3u64),
        (gen::grid2d(18, 18), 4),
        (gen::kkt(150, 50, 3), 5), // perturbation → refinement engages
    ] {
        for threads in [1usize, 3] {
            let solver = Solver::new(SolverConfig {
                threads,
                parallel_solve_min_n: 0,
                ..SolverConfig::default()
            });
            let an = solver.analyze(&a).unwrap();
            let f = solver.factor(&a, &an).unwrap();
            let bs = rhs_set(a.n, 5, seed);
            let xs = solver.solve_many(&a, &an, &f, &bs).unwrap();
            assert_eq!(xs.len(), bs.len());
            for (q, b) in bs.iter().enumerate() {
                let x = solver.solve(&a, &an, &f, b).unwrap();
                assert_eq!(
                    xs[q], x,
                    "batched column {q} must be bit-identical (t={threads})"
                );
            }
        }
    }
}

#[test]
fn solve_many_k1_matches_scalar_solve() {
    let a = gen::circuit(400, 2);
    let solver = Solver::new(SolverConfig::default());
    let an = solver.analyze(&a).unwrap();
    let f = solver.factor(&a, &an).unwrap();
    let b = gen::rhs_for_ones(&a);
    let xs = solver.solve_many(&a, &an, &f, &[b.clone()]).unwrap();
    let x = solver.solve(&a, &an, &f, &b).unwrap();
    assert_eq!(xs[0], x);
}

#[test]
fn analysis_plan_matches_pool_width() {
    let a = gen::grid2d(10, 10);
    for threads in [1usize, 2, 5] {
        let solver = Solver::new(SolverConfig {
            threads,
            ..SolverConfig::default()
        });
        let an = solver.analyze(&a).unwrap();
        assert_eq!(an.plan.nthreads, solver.engine().pool().nthreads());
        assert_eq!(an.plan.factor_chunks.len(), an.sym.schedule.bulk_levels);
    }
}

#[test]
fn alternating_two_analyses_stays_allocation_free_when_warm() {
    // one solver serving two systems per tick: both permuted-matrix cache
    // entries (and the shared done-flag/workspace arenas) must stay warm
    let a1 = gen::grid2d(14, 14);
    let a2 = gen::power_network(200, 5);
    let solver = Solver::new(SolverConfig {
        threads: 2,
        parallel_solve_min_n: 0,
        ..SolverConfig::default()
    });
    let an1 = solver.analyze(&a1).unwrap();
    let an2 = solver.analyze(&a2).unwrap();
    let mut f1 = solver.factor(&a1, &an1).unwrap();
    let mut f2 = solver.factor(&a2, &an2).unwrap();
    let b1 = gen::rhs_for_ones(&a1);
    let b2 = gen::rhs_for_ones(&a2);
    let (mut x1, mut x2) = (Vec::new(), Vec::new());
    // warm-up tick for both systems
    solver.refactor(&a1, &an1, &mut f1).unwrap();
    solver.solve_into(&a1, &an1, &f1, &b1, &mut x1).unwrap();
    solver.refactor(&a2, &an2, &mut f2).unwrap();
    solver.solve_into(&a2, &an2, &f2, &b2, &mut x2).unwrap();
    let spawned = solver.engine().threads_spawned();
    let allocs = solver.engine().scratch_alloc_events();
    for _ in 0..3 {
        solver.refactor(&a1, &an1, &mut f1).unwrap();
        solver.solve_into(&a1, &an1, &f1, &b1, &mut x1).unwrap();
        solver.refactor(&a2, &an2, &mut f2).unwrap();
        solver.solve_into(&a2, &an2, &f2, &b2, &mut x2).unwrap();
    }
    assert_eq!(solver.engine().threads_spawned(), spawned);
    assert_eq!(
        solver.engine().scratch_alloc_events(),
        allocs,
        "alternating warm systems must not re-clone the permuted cache"
    );
}

#[test]
fn engine_survives_many_analyses_and_mixed_sizes() {
    // switching between systems of different size on one engine must stay
    // correct (arenas are high-water sized, larger n regrows them)
    let solver = Solver::new(SolverConfig {
        threads: 2,
        parallel_solve_min_n: 0,
        ..SolverConfig::default()
    });
    for a in [gen::grid2d(8, 8), gen::grid2d(20, 20), gen::grid2d(5, 5)] {
        let an = solver.analyze(&a).unwrap();
        let f = solver.factor(&a, &an).unwrap();
        let xt: Vec<f64> = (0..a.n).map(|i| (i % 6) as f64 - 2.0).collect();
        let mut b = vec![0.0; a.n];
        a.matvec(&xt, &mut b);
        let x = solver.solve(&a, &an, &f, &b).unwrap();
        let err = hylu::testutil::max_abs_diff(&x, &xt);
        assert!(err < 1e-8, "n={} err={err}", a.n);
    }
}

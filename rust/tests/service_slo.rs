//! SLO regression tests for the dispatcher's coalescing window and the
//! elastic shard set.
//!
//! The coalescing window used to be a bare `thread::sleep`: once a
//! dispatcher entered it, nothing — not a control job, not a full
//! batch, not a deadline admitted with time to spare — could wake the
//! shard until the whole window elapsed. These tests pin the fixed
//! behavior with windows large enough (hundreds of milliseconds) that a
//! regression to uninterruptible sleeping fails by an order of
//! magnitude, not by a scheduler-jitter margin:
//!
//! - a control job (refactor) submitted mid-window completes well under
//!   one window;
//! - with `expire_deadlines` on, a request admitted with its deadline
//!   still live is *dispatched* (wake clamped to deadline − margin),
//!   never expired by the shard's own sleep;
//! - `ServiceStats::max_tick` records the wait actually slept, not the
//!   window requested, so preemption is visible in telemetry;
//! - `grow`/`shrink` move a live service between shard-set sizes with
//!   bit-identical answers, folded stats, and a monotonic shard epoch;
//! - per-call [`SolveOpts`] never bleed across a batch: default-opts
//!   traffic interleaved with override traffic stays bit-identical to
//!   the plain front door.

use std::time::{Duration, Instant};

use hylu::prelude::*;
use hylu::sparse::gen;
use hylu::testutil::Prng;
use hylu::Error;

fn slo_cfg(shards: usize, tick: Duration) -> ServiceConfig {
    ServiceConfig {
        shards,
        solver: SolverConfig {
            threads: 1,
            ..SolverConfig::default()
        },
        max_batch: 16,
        queue_cap: 1024,
        tick,
        tick_max: Duration::ZERO, // static window: the worst case
        ..ServiceConfig::default()
    }
}

/// A standalone handle configured identically to the service's solver,
/// so its bits are the oracle for served solutions.
fn oracle(a: &Csr) -> LinearSystem<Factored> {
    let solver = SolverBuilder::new().threads(1).pin_fault().build().unwrap();
    solver.analyze(a).unwrap().factor().unwrap()
}

#[test]
fn control_job_preempts_the_coalescing_window() {
    // One lone bulk solve opens a 400ms window; the refactor submitted
    // right behind it must break that window, not sleep it out.
    let a = gen::power_network(150, 4);
    let window = Duration::from_millis(400);
    let service = SolverService::new(slo_cfg(1, window), vec![a.clone()]).unwrap();
    let id = service.system_ids()[0];
    let b = gen::rhs_for_ones(&a);

    let expect_v0 = oracle(&a).solve(&b).unwrap();
    let mut a2 = a.clone();
    for v in &mut a2.vals {
        *v *= 1.5;
    }
    let mut ora2 = oracle(&a);
    ora2.refactor(&a2.vals).unwrap();
    let expect_v1 = ora2.solve(&b).unwrap();

    // the solve is admitted first (seq order), so it observes v0; the
    // refactor is a barrier behind it
    let t = service.submit(id, b.clone()).unwrap();
    let t0 = Instant::now();
    service.refactor(id, a2).unwrap();
    let waited = t0.elapsed();
    assert!(
        waited < window / 2,
        "refactor blocked {waited:?}: the control job slept through the \
         {window:?} coalescing window instead of preempting it"
    );
    assert_eq!(t.wait().unwrap(), expect_v0, "pre-barrier solve sees v0");
    assert_eq!(service.solve(id, b).unwrap(), expect_v1, "post-barrier solve sees v1");
}

#[test]
fn live_deadline_is_dispatched_not_slept_past() {
    // expire_deadlines on, 400ms static window, 60ms deadlines: every
    // request is admitted alive with slack well inside the window, so
    // under the old bare sleep each one would expire at dispatch. The
    // SLO-aware wait clamps the wake to (deadline − margin) instead.
    let a = gen::power_network(150, 4);
    let mut cfg = slo_cfg(1, Duration::from_millis(400));
    cfg.expire_deadlines = true;
    cfg.dispatch_margin = Duration::from_millis(15);
    let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
    let id = service.system_ids()[0];
    let b = gen::rhs_for_ones(&a);
    let expect = oracle(&a).solve(&b).unwrap();

    for round in 0..6 {
        // alternate arrival orders: the deadline either opens the window
        // itself or lands mid-window behind a bulk request — the clamp
        // must hold in both
        let bulk = (round % 2 == 0)
            .then(|| service.submit(id, b.clone()).unwrap());
        let x = service
            .solve_with(
                id,
                b.clone(),
                Priority::Deadline(Instant::now() + Duration::from_millis(60)),
            )
            .unwrap_or_else(|e| {
                panic!(
                    "round {round}: live-admitted deadline failed with {e}: \
                     the shard slept past its own deadline"
                )
            });
        assert_eq!(x, expect, "round {round}");
        if let Some(t) = bulk {
            assert_eq!(t.wait().unwrap(), expect, "round {round} bulk");
        }
    }
    let st = service.stats();
    assert_eq!(st.expired, 0, "no admitted-live request expired");
    assert_eq!(st.deadline_requests, 6);
}

#[test]
fn max_tick_records_slept_not_requested() {
    // max_batch 2 and paired submissions: the second push of each pair
    // fills the batch and breaks the window, so no wait ever approaches
    // the requested 300ms. The old telemetry recorded the *requested*
    // window and would report ~300ms here.
    let a = gen::power_network(150, 4);
    let window = Duration::from_millis(300);
    let mut cfg = slo_cfg(1, window);
    cfg.max_batch = 2;
    let service = SolverService::new(cfg, vec![a.clone()]).unwrap();
    let id = service.system_ids()[0];
    let b = gen::rhs_for_ones(&a);
    let expect = oracle(&a).solve(&b).unwrap();

    for _ in 0..4 {
        let t1 = service.submit(id, b.clone()).unwrap();
        let t2 = service.submit(id, b.clone()).unwrap();
        assert_eq!(t1.wait().unwrap(), expect);
        assert_eq!(t2.wait().unwrap(), expect);
    }
    let st = service.stats();
    assert!(
        st.max_tick < window / 2,
        "max_tick {:?} reports the requested window, not the {:?}-scale \
         wait actually slept",
        st.max_tick,
        window
    );
}

#[test]
fn grow_and_shrink_preserve_answers_and_fold_stats() {
    let base = gen::power_network(180, 4);
    let nsys = 4usize;
    let systems: Vec<Csr> = (0..nsys)
        .map(|s| {
            let mut m = base.clone();
            let f = 1.0 + 0.3 * s as f64;
            for v in &mut m.vals {
                *v *= f;
            }
            m
        })
        .collect();
    let mut rng = Prng::new(0x51);
    let bs: Vec<Vec<f64>> = (0..nsys)
        .map(|_| (0..base.n).map(|_| rng.normal()).collect())
        .collect();
    let expect: Vec<Vec<f64>> = systems
        .iter()
        .zip(&bs)
        .map(|(m, b)| oracle(m).solve(b).unwrap())
        .collect();

    let service = SolverService::new(
        slo_cfg(2, Duration::from_micros(50)),
        systems.clone(),
    )
    .unwrap();
    let ids = service.system_ids();
    assert_eq!(service.shard_count(), 2);
    let epoch0 = service.shard_epoch();

    // grow: new dispatchers join the set, rebalance spreads load onto
    // them, and every answer stays bit-identical
    assert_eq!(service.grow(2).unwrap(), 4);
    assert_eq!(service.shard_count(), 4);
    assert!(service.shard_epoch() > epoch0, "grow published a new epoch");
    service.rebalance().unwrap();
    for (s, id) in ids.iter().enumerate() {
        assert_eq!(service.solve(*id, bs[s].clone()).unwrap(), expect[s], "after grow");
    }

    // shrink to one shard: every system is drained onto the survivor,
    // stays healthy, and still answers bit-identically
    let epoch_grown = service.shard_epoch();
    assert_eq!(service.shrink(3).unwrap(), 1);
    assert_eq!(service.shard_count(), 1);
    assert!(service.shard_epoch() > epoch_grown, "shrink published a new epoch");
    for (s, id) in ids.iter().enumerate() {
        assert!(
            matches!(service.health(*id), Some(Health::Healthy)),
            "system {s} healthy after drain"
        );
        assert_eq!(service.solve(*id, bs[s].clone()).unwrap(), expect[s], "after shrink");
    }

    // counters from the drained shards folded into the totals
    let st = service.stats();
    assert_eq!(st.registers as usize, nsys);
    assert_eq!(st.requests as usize, 2 * nsys);
    assert_eq!(st.rhs_solved as usize, 2 * nsys);

    // the last shard must remain
    let err = service.shrink(1).unwrap_err();
    assert!(
        matches!(err, Error::Invalid(_)),
        "shrinking the last shard must be rejected, got {err}"
    );
    // no-op edges
    assert_eq!(service.grow(0).unwrap(), 1);
    assert_eq!(service.shrink(0).unwrap(), 1);
}

#[test]
fn solve_opts_never_bleed_across_a_batch() {
    // one shard, a wide window, and interleaved submissions: default
    // opts and per-call overrides coalesce only with their own kind, so
    // the default tickets stay bit-identical to the plain front door
    let a = gen::power_network(150, 4);
    let service = SolverService::new(
        slo_cfg(1, Duration::from_micros(500)),
        vec![a.clone()],
    )
    .unwrap();
    let id = service.system_ids()[0];
    let ora = oracle(&a);
    let mut rng = Prng::new(0x0975);
    for round in 0..8 {
        let b: Vec<f64> = (0..a.n).map(|_| rng.normal()).collect();
        let expect = ora.solve(&b).unwrap();
        let raw = SolveOpts::new().refine_max_iter(0);
        let tickets = vec![
            service.submit_with_opts(id, b.clone(), Priority::Bulk, SolveOpts::new()).unwrap(),
            service.submit_with_opts(id, b.clone(), Priority::Bulk, raw).unwrap(),
            service.submit(id, b.clone()).unwrap(),
        ];
        let [x_default, x_raw, x_plain]: [Vec<f64>; 3] = tickets
            .into_iter()
            .map(|t| t.wait().unwrap())
            .collect::<Vec<_>>()
            .try_into()
            .unwrap();
        assert_eq!(x_default, expect, "round {round}: default opts == plain solve");
        assert_eq!(x_plain, expect, "round {round}: plain submit unaffected");
        // refinement off still lands close on this well-conditioned
        // system — it just may not share the refined bits
        let resid = x_raw
            .iter()
            .zip(&expect)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0f64, f64::max);
        assert!(resid < 1e-6, "round {round}: raw substitution drifted {resid:.3e}");
    }
    // blocking front door with overrides agrees with itself
    let b = gen::rhs_for_ones(&a);
    let x1 = service
        .solve_with_opts(id, b.clone(), Priority::Bulk, SolveOpts::new().refine_target(1e-14))
        .unwrap();
    let x2 = service
        .solve_with_opts(id, b, Priority::Bulk, SolveOpts::new().refine_target(1e-14))
        .unwrap();
    assert_eq!(x1, x2, "same opts, same bits");
}

//! Regression test for the stale-calibration bug: re-pinning the
//! dispatch tier with `set_tier` must invalidate the cached throughput
//! probe so kernel selection is calibrated against the tier actually
//! dispatching (the old `OnceLock` probe kept the first tier's
//! measurement forever).
//!
//! `set_tier` is process-global state, so this whole scenario lives in
//! ONE test function in its OWN test binary — it must not run next to
//! tests that assume the default tier.

use hylu::numeric::kernels::{self, KernelTier};

#[test]
fn probe_and_calibration_follow_tier_repinning() {
    let original = kernels::active_tier();

    // pin scalar: the probe must measure scalar (advantage ~1 by
    // construction: the probe races the tier kernel against the scalar
    // reference, and here they are the same kernel)
    kernels::set_tier(KernelTier::Scalar);
    let p_scalar = kernels::probe();
    assert_eq!(p_scalar.tier, KernelTier::Scalar);
    assert!(
        p_scalar.advantage() > 0.3 && p_scalar.advantage() < 3.0,
        "scalar-vs-scalar probe advantage should be near 1, got {:.2}",
        p_scalar.advantage()
    );

    // re-pin portable: the cached scalar probe is stale and must be
    // re-measured, not returned
    kernels::set_tier(KernelTier::Portable);
    let p_portable = kernels::probe();
    assert_eq!(
        p_portable.tier,
        KernelTier::Portable,
        "probe returned a stale measurement from the previous tier"
    );

    // repeated reads without a tier change reuse the cached measurement
    let again = kernels::probe();
    assert_eq!(again.tier, KernelTier::Portable);
    assert_eq!(again.gemm_gflops.to_bits(), p_portable.gemm_gflops.to_bits());
    assert_eq!(again.scalar_gflops.to_bits(), p_portable.scalar_gflops.to_bits());

    // calibration always reflects the *current* tier's probe and stays in
    // its clamped stability band
    for tier in [KernelTier::Scalar, KernelTier::Portable, KernelTier::Native, KernelTier::Avx512]
    {
        if !tier.available() {
            continue;
        }
        kernels::set_tier(tier);
        let c = kernels::calibration();
        assert!(
            (0.9..=1.5).contains(&c),
            "calibration for {tier} out of band: {c:.3}"
        );
        assert_eq!(kernels::probe().tier, tier);
    }

    kernels::set_tier(original);
}
